//! 2D tile-grid layouts: a matrix dealt as `tile_r × tile_c` tiles onto
//! a `P × Q` device grid.
//!
//! The paper names the 2D block-cyclic distribution as the key piece of
//! future work (§5): cuSOLVERMg's 1D column layout leaves `syevd`'s
//! tridiagonal reduction row-bound — every device owns *whole rows* of
//! its columns, so the per-step Householder collectives carry full
//! length-`n` vectors through one owner. On a `P × Q` grid each vector
//! is born distributed across `P` row blocks and the collectives run as
//! `P` parallel row-group transfers of `n/P` words (ScaLAPACK's classic
//! argument; Dongarra, van de Geijn & Walker 1994).
//!
//! The model is a Cartesian product of two 1D *tile deals*
//! ([`TileDim`]): rows grouped into `tile_r`-high tiles dealt to `P`
//! grid rows, columns into `tile_c`-wide tiles dealt to `Q` grid
//! columns; tile `(tr, tc)` lives on device `(row_owner(tr),
//! col_owner(tc))`. Two deals per dimension cover the system's needs:
//!
//! * **cyclic** — round-robin tiles, the load-balanced compute layout
//!   ([`BlockCyclic2D`], the `cusolverMg` future-work analogue);
//! * **blocked** — contiguous runs of tiles, the JAX 2D-mesh shard
//!   input layout ([`ContiguousGrid2D`]).
//!
//! **Storage contract.** Device `(r, c)` holds one allocation of
//! `local_rows × local_cols` scalars in *tile-major* order: local tile
//! columns left to right, tiles within a tile column top to bottom,
//! each tile itself column-major and contiguous. With `P = 1` and
//! `tile_r ≥ m` every tile is a full-height group of `tile_c` columns,
//! so the storage degenerates **bitwise** to the 1D column-panel
//! contract of [`super::ColumnLayout`] — which is how the existing 1D
//! layouts are subsumed as the `P = 1` special case and the 1D solvers
//! keep running unchanged on 2D handles (see
//! [`crate::tile::LayoutKind::compat_1d`]).

use super::block_cyclic::BlockCyclic1D;
use crate::error::{Error, Result};

/// How tiles along one dimension are dealt to that dimension's devices.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
enum Deal {
    /// Round-robin: tile `t` → device `t mod nd`.
    Cyclic,
    /// Contiguous blocks of tiles, sizes differing by at most one.
    Blocked,
}

/// One dimension of a tile-grid distribution: `extent` indices grouped
/// into tiles of `tile`, dealt to `nd` devices. All 2D layout
/// arithmetic factors through two of these (rows × columns).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct TileDim {
    extent: usize,
    tile: usize,
    nd: usize,
    deal: Deal,
}

impl TileDim {
    fn new(extent: usize, tile: usize, nd: usize, deal: Deal) -> Result<Self> {
        if tile == 0 {
            return Err(Error::layout("tile size must be positive"));
        }
        if nd == 0 {
            return Err(Error::layout("need at least one device along each grid dimension"));
        }
        Ok(TileDim { extent, tile, nd, deal })
    }

    /// Round-robin tile deal.
    pub fn cyclic(extent: usize, tile: usize, nd: usize) -> Result<Self> {
        Self::new(extent, tile, nd, Deal::Cyclic)
    }

    /// Contiguous-block tile deal.
    pub fn blocked(extent: usize, tile: usize, nd: usize) -> Result<Self> {
        Self::new(extent, tile, nd, Deal::Blocked)
    }

    /// One-item-per-tile round-robin deal: item `i` → device `i mod nd`,
    /// local ordinal `i / nd`. This is the degenerate cyclic deal the
    /// batched small-solve pods ([`crate::batch::PackedPod`]) use to
    /// spread `count` independent systems over the node — the same
    /// `numroc` arithmetic as the tile grids, at tile size 1.
    pub fn round_robin(count: usize, nd: usize) -> Result<Self> {
        Self::cyclic(count, 1, nd)
    }

    /// Total indices along this dimension.
    pub fn extent(&self) -> usize {
        self.extent
    }

    /// Tile length (the last tile may be short).
    pub fn tile(&self) -> usize {
        self.tile
    }

    /// Devices along this dimension.
    pub fn devices(&self) -> usize {
        self.nd
    }

    /// Number of tiles (the last may be short).
    pub fn num_tiles(&self) -> usize {
        self.extent.div_ceil(self.tile)
    }

    /// Length of tile `t`.
    pub fn tile_len(&self, t: usize) -> usize {
        debug_assert!(t < self.num_tiles());
        if (t + 1) * self.tile <= self.extent {
            self.tile
        } else {
            self.extent - t * self.tile
        }
    }

    /// First index of tile `t`.
    pub fn tile_start(&self, t: usize) -> usize {
        t * self.tile
    }

    /// First tile of device `d`'s block (blocked deal arithmetic).
    fn block_start(&self, d: usize) -> usize {
        let nt = self.num_tiles();
        let base = nt / self.nd;
        let rem = nt % self.nd;
        d * base + d.min(rem)
    }

    /// Owning device of tile `t`.
    pub fn owner(&self, t: usize) -> usize {
        debug_assert!(t < self.num_tiles());
        match self.deal {
            Deal::Cyclic => t % self.nd,
            Deal::Blocked => {
                let nt = self.num_tiles();
                let base = nt / self.nd;
                let rem = nt % self.nd;
                let big = (base + 1) * rem;
                if t < big {
                    t / (base + 1)
                } else {
                    rem + (t - big) / base.max(1)
                }
            }
        }
    }

    /// Local tile ordinal of tile `t` on its owner (ascending in `t`).
    pub fn local(&self, t: usize) -> usize {
        match self.deal {
            Deal::Cyclic => t / self.nd,
            Deal::Blocked => t - self.block_start(self.owner(t)),
        }
    }

    /// Number of tiles owned by device `d`.
    pub fn count(&self, d: usize) -> usize {
        debug_assert!(d < self.nd);
        let nt = self.num_tiles();
        match self.deal {
            Deal::Cyclic => (nt + self.nd - 1 - d) / self.nd,
            Deal::Blocked => self.block_start(d + 1).min(nt) - self.block_start(d),
        }
    }

    /// Inverse of [`TileDim::local`]: the `l`-th tile of device `d`.
    pub fn at(&self, d: usize, l: usize) -> usize {
        debug_assert!(l < self.count(d));
        match self.deal {
            Deal::Cyclic => l * self.nd + d,
            Deal::Blocked => self.block_start(d) + l,
        }
    }

    /// Total indices stored on device `d` (`numroc` along one axis).
    /// Only the global last tile can be short, and it is always its
    /// owner's local last, so the closed form needs no loop.
    pub fn local_extent(&self, d: usize) -> usize {
        let c = self.count(d);
        if c == 0 {
            return 0;
        }
        let last = self.num_tiles() - 1;
        if self.owner(last) == d {
            (c - 1) * self.tile + self.tile_len(last)
        } else {
            c * self.tile
        }
    }
}

/// A 2D tile placement: `(row, col) → (device, local storage offset)`.
///
/// Everything is derived from the two [`TileDim`] deals; implementors
/// only supply those. The device grid is row-major: grid coordinate
/// `(r, c)` is device ordinal `r·Q + c`.
pub trait MatrixLayout {
    /// The row-dimension tile deal.
    fn row_dim(&self) -> TileDim;
    /// The column-dimension tile deal.
    fn col_dim(&self) -> TileDim;

    /// `(m, n)` matrix shape.
    fn shape(&self) -> (usize, usize) {
        (self.row_dim().extent(), self.col_dim().extent())
    }

    /// `(tile_r, tile_c)` tile shape.
    fn tile_shape(&self) -> (usize, usize) {
        (self.row_dim().tile(), self.col_dim().tile())
    }

    /// `(P, Q)` device grid shape.
    fn grid(&self) -> (usize, usize) {
        (self.row_dim().devices(), self.col_dim().devices())
    }

    /// Total devices (`P·Q`).
    fn num_devices(&self) -> usize {
        let (p, q) = self.grid();
        p * q
    }

    /// Device ordinal of grid coordinate `(r, c)`.
    fn device_of(&self, r: usize, c: usize) -> usize {
        r * self.grid().1 + c
    }

    /// Grid coordinate of device ordinal `d`.
    fn device_coords(&self, d: usize) -> (usize, usize) {
        let q = self.grid().1;
        (d / q, d % q)
    }

    /// `(tile rows, tile cols)` of the global tile grid.
    fn tile_grid(&self) -> (usize, usize) {
        (self.row_dim().num_tiles(), self.col_dim().num_tiles())
    }

    /// Actual `(height, width)` of tile `(tr, tc)` (edges may be short).
    fn tile_dims(&self, tr: usize, tc: usize) -> (usize, usize) {
        (self.row_dim().tile_len(tr), self.col_dim().tile_len(tc))
    }

    /// Owning device of tile `(tr, tc)`.
    fn owner_of_tile(&self, tr: usize, tc: usize) -> usize {
        self.device_of(self.row_dim().owner(tr), self.col_dim().owner(tc))
    }

    /// Storage ordinal of tile `(tr, tc)` on its owner: local tile
    /// columns left to right, top to bottom within a tile column.
    fn local_tile_ordinal(&self, tr: usize, tc: usize) -> usize {
        let rd = self.row_dim();
        let cd = self.col_dim();
        cd.local(tc) * rd.count(rd.owner(tr)) + rd.local(tr)
    }

    /// Inverse of [`MatrixLayout::local_tile_ordinal`] for device `d`.
    fn tile_at(&self, d: usize, ordinal: usize) -> (usize, usize) {
        let (r, c) = self.device_coords(d);
        let rd = self.row_dim();
        let cd = self.col_dim();
        let ltr = rd.count(r);
        debug_assert!(ltr > 0, "device owns no tile rows");
        (rd.at(r, ordinal % ltr), cd.at(c, ordinal / ltr))
    }

    /// Number of tiles stored on device `d`.
    fn tiles_on(&self, d: usize) -> usize {
        let (r, c) = self.device_coords(d);
        self.row_dim().count(r) * self.col_dim().count(c)
    }

    /// `(local_rows, local_cols)` stored on device `d`.
    fn local_shape(&self, d: usize) -> (usize, usize) {
        let (r, c) = self.device_coords(d);
        (self.row_dim().local_extent(r), self.col_dim().local_extent(c))
    }

    /// Scalars stored on device `d`.
    fn local_elems(&self, d: usize) -> usize {
        let (lr, lc) = self.local_shape(d);
        lr * lc
    }

    /// Whether every tile is full-sized (no ragged edge tiles) — the
    /// precondition for the in-place tile cycle walk.
    fn uniform_tiles(&self) -> bool {
        let (m, n) = self.shape();
        let (tr, tc) = self.tile_shape();
        m % tr == 0 && n % tc == 0
    }

    /// Storage offset (in scalars) of the first element of tile
    /// `(tr, tc)` within its owner's allocation. Tiles above it in the
    /// same local tile column are all full-height (a short tile row is
    /// globally last, hence locally last), so the prefix is closed-form.
    fn tile_elem_offset(&self, tr: usize, tc: usize) -> usize {
        let rd = self.row_dim();
        let cd = self.col_dim();
        let r = rd.owner(tr);
        rd.local_extent(r) * (cd.local(tc) * cd.tile())
            + rd.local(tr) * rd.tile() * cd.tile_len(tc)
    }

    /// `(device, storage offset in scalars)` of element `(i, j)`.
    fn place_elem(&self, i: usize, j: usize) -> (usize, usize) {
        let rd = self.row_dim();
        let cd = self.col_dim();
        let (tr, ii) = (i / rd.tile(), i % rd.tile());
        let (tc, jj) = (j / cd.tile(), j % cd.tile());
        let d = self.owner_of_tile(tr, tc);
        let off = self.tile_elem_offset(tr, tc) + jj * rd.tile_len(tr) + ii;
        (d, off)
    }
}

/// The ScaLAPACK-style 2D block-cyclic deal — the compute layout the
/// paper lists as future work. `P = 1` with `tile_r ≥ m` reduces to
/// [`BlockCyclic1D`] with bitwise-identical storage.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct BlockCyclic2D {
    rows: TileDim,
    cols: TileDim,
}

impl BlockCyclic2D {
    /// New `m × n` matrix in `tile_r × tile_c` tiles on a `p × q` grid.
    pub fn new(m: usize, n: usize, tile_r: usize, tile_c: usize, p: usize, q: usize) -> Result<Self> {
        Ok(BlockCyclic2D {
            rows: TileDim::cyclic(m, tile_r, p)?,
            cols: TileDim::cyclic(n, tile_c, q)?,
        })
    }

    /// Matrix rows.
    pub fn rows(&self) -> usize {
        self.rows.extent()
    }

    /// Matrix columns.
    pub fn cols(&self) -> usize {
        self.cols.extent()
    }

    /// Tile height.
    pub fn tile_r(&self) -> usize {
        self.rows.tile()
    }

    /// Tile width.
    pub fn tile_c(&self) -> usize {
        self.cols.tile()
    }

    /// Grid rows `P`.
    pub fn p(&self) -> usize {
        self.rows.devices()
    }

    /// Grid columns `Q`.
    pub fn q(&self) -> usize {
        self.cols.devices()
    }

    /// The equivalent 1D column layout when this grid has a single row
    /// of full-height tiles (`P = 1`, `tile_r ≥ m`) — the compatibility
    /// path the 1D solvers run on.
    pub fn as_column_layout(&self) -> Option<BlockCyclic1D> {
        if self.p() == 1 && self.tile_r() >= self.rows().max(1) {
            BlockCyclic1D::new(self.cols(), self.tile_c(), self.q()).ok()
        } else {
            None
        }
    }
}

impl MatrixLayout for BlockCyclic2D {
    fn row_dim(&self) -> TileDim {
        self.rows
    }
    fn col_dim(&self) -> TileDim {
        self.cols
    }
}

/// The 2D-mesh shard input layout: contiguous blocks of tiles per grid
/// row/column — what `NamedSharding(mesh2d, P("x", "y"))` hands the
/// backend, tile-granular.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct ContiguousGrid2D {
    rows: TileDim,
    cols: TileDim,
}

impl ContiguousGrid2D {
    /// New `m × n` matrix in `tile_r × tile_c` tiles, blocked onto a
    /// `p × q` grid.
    pub fn new(m: usize, n: usize, tile_r: usize, tile_c: usize, p: usize, q: usize) -> Result<Self> {
        Ok(ContiguousGrid2D {
            rows: TileDim::blocked(m, tile_r, p)?,
            cols: TileDim::blocked(n, tile_c, q)?,
        })
    }
}

impl MatrixLayout for ContiguousGrid2D {
    fn row_dim(&self) -> TileDim {
        self.rows
    }
    fn col_dim(&self) -> TileDim {
        self.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::ColumnLayout;

    /// Every element maps to exactly one (device, offset) pair, offsets
    /// tile the local allocations exactly, and tile ordinals invert.
    fn check_grid_bijection(l: &dyn MatrixLayout) {
        let (m, n) = l.shape();
        let nd = l.num_devices();
        let mut seen: Vec<Vec<bool>> = (0..nd).map(|d| vec![false; l.local_elems(d)]).collect();
        for j in 0..n {
            for i in 0..m {
                let (d, off) = l.place_elem(i, j);
                assert!(d < nd, "device {d} out of range");
                assert!(off < seen[d].len(), "offset {off} past local_elems on dev {d}");
                assert!(!seen[d][off], "element ({i},{j}) collides on dev {d} at {off}");
                seen[d][off] = true;
            }
        }
        for (d, s) in seen.iter().enumerate() {
            assert!(s.iter().all(|&b| b), "holes in device {d}'s storage");
        }
        // Tile ordinals are a bijection per device.
        let (tr_n, tc_n) = l.tile_grid();
        let mut counts = vec![0usize; nd];
        for tr in 0..tr_n {
            for tc in 0..tc_n {
                let d = l.owner_of_tile(tr, tc);
                let ord = l.local_tile_ordinal(tr, tc);
                assert_eq!(l.tile_at(d, ord), (tr, tc));
                counts[d] += 1;
            }
        }
        for d in 0..nd {
            assert_eq!(counts[d], l.tiles_on(d), "tiles_on mismatch on dev {d}");
        }
        let total: usize = (0..nd).map(|d| l.local_elems(d)).sum();
        assert_eq!(total, m * n);
    }

    #[test]
    fn block_cyclic_2d_bijection_even() {
        let l = BlockCyclic2D::new(16, 16, 4, 4, 2, 2).unwrap();
        check_grid_bijection(&l);
    }

    #[test]
    fn block_cyclic_2d_bijection_ragged() {
        for (m, n, tr, tc, p, q) in [
            (10, 14, 4, 3, 2, 2),
            (7, 5, 3, 2, 3, 2),
            (9, 9, 2, 5, 2, 3),
            (1, 1, 1, 1, 1, 1),
            (5, 8, 8, 2, 1, 4),
            (12, 6, 5, 7, 4, 1),
        ] {
            let l = BlockCyclic2D::new(m, n, tr, tc, p, q).unwrap();
            check_grid_bijection(&l);
        }
    }

    #[test]
    fn contiguous_grid_bijection() {
        for (m, n, tr, tc, p, q) in [(12, 12, 2, 2, 2, 3), (10, 9, 3, 4, 2, 2), (6, 6, 2, 2, 4, 4)] {
            let l = ContiguousGrid2D::new(m, n, tr, tc, p, q).unwrap();
            check_grid_bijection(&l);
        }
    }

    #[test]
    fn p1_matches_1d_block_cyclic_storage_bitwise() {
        // P = 1 with full-height tiles: (device, offset) must equal the
        // 1D column layout's (owner, local*m + i) for every element.
        for (m, n, t, q) in [(8, 12, 2, 3), (5, 14, 3, 4), (6, 10, 4, 2)] {
            let g = BlockCyclic2D::new(m, n, m, t, 1, q).unwrap();
            let l1 = g.as_column_layout().expect("P=1 grid has a column view");
            for j in 0..n {
                for i in 0..m {
                    let (d, off) = g.place_elem(i, j);
                    assert_eq!(d, l1.owner_of(j), "owner mismatch at ({i},{j})");
                    assert_eq!(off, l1.local_index(j) * m + i, "offset mismatch at ({i},{j})");
                }
            }
            for d in 0..q {
                assert_eq!(g.local_shape(d), (m, l1.local_cols(d)));
            }
        }
    }

    #[test]
    fn p_greater_one_has_no_column_view() {
        let g = BlockCyclic2D::new(8, 8, 4, 4, 2, 2).unwrap();
        assert!(g.as_column_layout().is_none());
        let g2 = BlockCyclic2D::new(8, 8, 4, 4, 1, 4).unwrap(); // tile_r < m
        assert!(g2.as_column_layout().is_none());
    }

    #[test]
    fn round_robin_tile_owners() {
        // 4×4 tiles on a 2×2 grid: owner (tr%2, tc%2).
        let l = BlockCyclic2D::new(16, 16, 4, 4, 2, 2).unwrap();
        assert_eq!(l.owner_of_tile(0, 0), 0);
        assert_eq!(l.owner_of_tile(0, 1), 1);
        assert_eq!(l.owner_of_tile(1, 0), 2);
        assert_eq!(l.owner_of_tile(3, 3), 3);
        assert_eq!(l.owner_of_tile(2, 2), 0);
        assert_eq!(l.local_shape(0), (8, 8));
    }

    #[test]
    fn ragged_edge_tile_dims() {
        let l = BlockCyclic2D::new(10, 14, 4, 3, 2, 2).unwrap();
        assert_eq!(l.tile_grid(), (3, 5));
        assert_eq!(l.tile_dims(2, 4), (2, 2)); // both edges short
        assert_eq!(l.tile_dims(0, 0), (4, 3));
        assert!(!l.uniform_tiles());
        let u = BlockCyclic2D::new(12, 12, 4, 3, 2, 2).unwrap();
        assert!(u.uniform_tiles());
    }

    #[test]
    fn tile_dim_invariants() {
        for dim in [
            TileDim::cyclic(17, 3, 4).unwrap(),
            TileDim::blocked(17, 3, 4).unwrap(),
            TileDim::cyclic(4, 8, 3).unwrap(), // fewer tiles than devices
            TileDim::blocked(4, 8, 3).unwrap(),
        ] {
            let nt = dim.num_tiles();
            let mut total_tiles = 0;
            let mut total_extent = 0;
            for d in 0..dim.devices() {
                let c = dim.count(d);
                total_tiles += c;
                total_extent += dim.local_extent(d);
                for l in 0..c {
                    let t = dim.at(d, l);
                    assert_eq!(dim.owner(t), d);
                    assert_eq!(dim.local(t), l);
                }
            }
            assert_eq!(total_tiles, nt);
            assert_eq!(total_extent, dim.extent());
            let len_sum: usize = (0..nt).map(|t| dim.tile_len(t)).sum();
            assert_eq!(len_sum, dim.extent());
        }
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(BlockCyclic2D::new(8, 8, 0, 2, 2, 2).is_err());
        assert!(BlockCyclic2D::new(8, 8, 2, 0, 2, 2).is_err());
        assert!(BlockCyclic2D::new(8, 8, 2, 2, 0, 2).is_err());
        assert!(ContiguousGrid2D::new(8, 8, 2, 2, 2, 0).is_err());
    }
}
