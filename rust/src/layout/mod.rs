//! Data distribution layer: how a matrix is dealt to the node's
//! devices, and how to convert between deals in place.
//!
//! Parallel dense factorizations need a cyclic layout for load balance
//! (Dongarra, van de Geijn & Walker 1994): with contiguous blocks, the
//! devices owning leading tiles go idle as the factorization sweeps
//! right; with round-robin tiles every device keeps working until the
//! end. The layer is organized around the **tile-grid model**: a matrix
//! is a grid of `tile_r × tile_c` tiles dealt onto a `P × Q` device
//! grid, and every distribution is a pair of 1D tile deals (rows ×
//! columns — see [`TileDim`]).
//!
//! * **1D column layouts** (`P = 1`, full-height tiles) — what
//!   cuSOLVERMg requires (`1 × Q` block-cyclic, [`BlockCyclic1D`]) and
//!   what JAX hands the backend (contiguous per-device shards,
//!   [`ContiguousBlock`]). Converting between the two in place is
//!   JAXMg's first technical contribution (paper §2.1, Figure 1). These
//!   keep their original [`ColumnLayout`] trait: explicit global↔local
//!   *column* index maps (ScaLAPACK `numroc`-style arithmetic).
//! * **2D tile-grid layouts** — the paper's named future work (§5):
//!   [`BlockCyclic2D`] (cyclic × cyclic, the compute layout that
//!   un-row-binds `syevd`'s tridiagonal reduction) and
//!   [`ContiguousGrid2D`] (blocked × blocked, the 2D-mesh shard input),
//!   both behind the [`MatrixLayout`] trait: `(row, col) → (device,
//!   local)` tile placement. A `P = 1` grid of full-height tiles has
//!   **bitwise-identical storage** to the 1D column layouts, which is
//!   how the existing solvers keep running on 2D handles.
//!
//! Conversions:
//!
//! 1. [`permutation_between`] / [`tile_permutation_between`]: the
//!    explicit source-slot → target-slot map of a layout conversion, at
//!    column or tile granularity, built through the `O(1)`-per-slot
//!    [`SlotMap`] / [`TileSlotMap`] precomputations.
//! 2. [`cycle_decomposition`]: disjoint permutation cycles.
//! 3. [`Redistributor`]: executes the cycles with peer-to-peer copies
//!    and **two staging buffers**, exactly as the paper describes —
//!    in place when the slot structures match (balanced 1D↔1D, or
//!    tile-compatible uniform 2D↔2D), and out of place otherwise
//!    (including the 1D↔2D re-tilings, which move per-column tile-row
//!    segments instead of whole slots).

mod block_cyclic;
mod cycles;
mod grid;
mod redistribute;

pub use block_cyclic::{BlockCyclic1D, ColumnLayout, ContiguousBlock};
pub use cycles::{
    cycle_decomposition, permutation_between, tile_permutation_between, Cycle, SlotMap,
    TileSlotMap,
};
pub use grid::{BlockCyclic2D, ContiguousGrid2D, MatrixLayout, TileDim};
pub use redistribute::{RedistPlan, Redistributor};
