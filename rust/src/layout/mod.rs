//! 1D block-cyclic data distribution (paper §2.1, Figure 1).
//!
//! Parallel dense factorizations need a cyclic layout for load balance
//! (Dongarra, van de Geijn & Walker 1994): with contiguous blocks, the
//! devices owning leading columns go idle as the factorization sweeps
//! right; with round-robin tiles every device keeps working until the
//! end. cuSOLVERMg requires a **1D column block-cyclic** layout, while
//! JAX hands the backend **contiguous per-device shards** — converting
//! between the two, in place, is JAXMg's first technical contribution:
//!
//! 1. [`BlockCyclic1D`] / [`ContiguousBlock`]: the two layouts as
//!    explicit global↔local column index maps (ScaLAPACK `numroc`-style
//!    arithmetic, with variable edge tiles).
//! 2. [`permutation_between`]: the explicit source-slot → target-slot
//!    map for a layout conversion.
//! 3. [`cycle_decomposition`]: disjoint permutation cycles.
//! 4. [`Redistributor`]: executes the cycles with peer-to-peer copies
//!    and **two staging buffers**, exactly as the paper describes, or
//!    out-of-place when the shapes make in-place rotation impossible.

mod block_cyclic;
mod cycles;
mod redistribute;

pub use block_cyclic::{BlockCyclic1D, ColumnLayout, ContiguousBlock};
pub use cycles::{cycle_decomposition, permutation_between, Cycle};
pub use redistribute::{RedistPlan, Redistributor};
