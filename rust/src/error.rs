//! Error types for the jaxmg crate.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes surfaced by the jaxmg stack.
///
/// The variants mirror the failure classes of the real system: CUDA
/// allocation failures (`DeviceOom`), invalid IPC handle use across
/// process boundaries, cuSOLVERMg status codes (`Solver`), and XLA/PJRT
/// load or execution errors (`Runtime`).
#[derive(Debug, Error)]
pub enum Error {
    /// Simulated device ran out of VRAM.
    #[error("device {device} out of memory: requested {requested} B, free {free} B of {capacity} B")]
    DeviceOom {
        device: usize,
        requested: usize,
        free: usize,
        capacity: usize,
    },

    /// An operation referenced a device id outside the node.
    #[error("invalid device id {device} (node has {count} devices)")]
    InvalidDevice { device: usize, count: usize },

    /// An operation referenced an allocation that does not exist (or was freed).
    #[error("invalid device pointer: device {device}, allocation {alloc_id}")]
    InvalidPointer { device: usize, alloc_id: u64 },

    /// Out-of-bounds access within an allocation.
    #[error("device buffer access out of bounds: offset {offset} + len {len} > size {size}")]
    OutOfBounds { offset: usize, len: usize, size: usize },

    /// IPC handle misuse (MPMD mode): opening in the exporting process,
    /// double-open, or open of a revoked handle.
    #[error("ipc error: {0}")]
    Ipc(String),

    /// Layout / sharding mismatch (bad tile size, spec mismatch, ...).
    #[error("layout error: {0}")]
    Layout(String),

    /// Numerical failure inside a solver, e.g. a non-positive-definite
    /// pivot in `potrf` (mirrors `CUSOLVER_STATUS_*` + `info > 0`).
    #[error("solver error: {0}")]
    Solver(String),

    /// The matrix was not positive definite: leading minor `minor` failed.
    #[error("matrix is not positive definite: leading minor {minor} is not positive")]
    NotPositiveDefinite { minor: usize },

    /// Eigensolver failed to converge within the iteration budget.
    #[error("eigensolver failed to converge at eigenvalue {index} after {iters} iterations")]
    NoConvergence { index: usize, iters: usize },

    /// Shape mismatch on a public API boundary.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// XLA/PJRT runtime errors (artifact missing, compile failure, ...).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Configuration errors from the builder / CLI.
    #[error("config error: {0}")]
    Config(String),

    /// Underlying XLA crate error.
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    /// IO errors (artifact files).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Helper for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }

    /// Helper for layout errors.
    pub fn layout(msg: impl Into<String>) -> Self {
        Error::Layout(msg.into())
    }

    /// Helper for solver errors.
    pub fn solver(msg: impl Into<String>) -> Self {
        Error::Solver(msg.into())
    }

    /// Helper for runtime errors.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }

    /// Helper for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }

    /// Helper for ipc errors.
    pub fn ipc(msg: impl Into<String>) -> Self {
        Error::Ipc(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_oom() {
        let e = Error::DeviceOom { device: 3, requested: 100, free: 10, capacity: 50 };
        let s = format!("{e}");
        assert!(s.contains("device 3"));
        assert!(s.contains("requested 100"));
    }

    #[test]
    fn helpers_construct_variants() {
        assert!(matches!(Error::shape("x"), Error::Shape(_)));
        assert!(matches!(Error::layout("x"), Error::Layout(_)));
        assert!(matches!(Error::solver("x"), Error::Solver(_)));
        assert!(matches!(Error::runtime("x"), Error::Runtime(_)));
        assert!(matches!(Error::config("x"), Error::Config(_)));
        assert!(matches!(Error::ipc("x"), Error::Ipc(_)));
    }
}
