//! Error types for the jaxmg crate.
//!
//! `Display`/`Error`/`From` are hand-implemented (no `thiserror`): the
//! workspace builds offline from a clean checkout, so the crate carries
//! no proc-macro dependencies.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes surfaced by the jaxmg stack.
///
/// The variants mirror the failure classes of the real system: CUDA
/// allocation failures (`DeviceOom`), invalid IPC handle use across
/// process boundaries, cuSOLVERMg status codes (`Solver`), and XLA/PJRT
/// load or execution errors (`Runtime`).
#[derive(Debug)]
pub enum Error {
    /// Simulated device ran out of VRAM.
    DeviceOom {
        device: usize,
        requested: usize,
        free: usize,
        capacity: usize,
    },

    /// An operation referenced a device id outside the node.
    InvalidDevice { device: usize, count: usize },

    /// An operation referenced an allocation that does not exist (or was freed).
    InvalidPointer { device: usize, alloc_id: u64 },

    /// Out-of-bounds access within an allocation.
    OutOfBounds { offset: usize, len: usize, size: usize },

    /// IPC handle misuse (MPMD mode): opening in the exporting process,
    /// double-open, or open of a revoked handle.
    Ipc(String),

    /// Layout / sharding mismatch (bad tile size, spec mismatch, ...).
    Layout(String),

    /// Numerical failure inside a solver, e.g. a non-positive-definite
    /// pivot in `potrf` (mirrors `CUSOLVER_STATUS_*` + `info > 0`).
    Solver(String),

    /// The matrix was not positive definite: leading minor `minor` failed.
    NotPositiveDefinite { minor: usize },

    /// Eigensolver failed to converge within the iteration budget.
    NoConvergence { index: usize, iters: usize },

    /// Mixed-precision iterative refinement hit its iteration cap (or
    /// stagnated) before reaching the requested tolerance. The caller
    /// falls back to the full-precision path; the residual reached is
    /// carried for the decision log.
    RefineStalled { iters: usize, residual: f64, tol: f64 },

    /// Shape mismatch on a public API boundary.
    Shape(String),

    /// XLA/PJRT runtime errors (artifact missing, compile failure, ...).
    Runtime(String),

    /// Configuration errors from the builder / CLI.
    Config(String),

    /// Every worker in an MPMD deployment is dead: there is no live
    /// device subset left to run the request on. Surfaced to the
    /// submitter instead of re-queueing forever.
    NoLiveWorkers { total: usize },

    /// Underlying XLA crate error.
    Xla(xla::Error),

    /// IO errors (artifact files).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::DeviceOom { device, requested, free, capacity } => write!(
                f,
                "device {device} out of memory: requested {requested} B, free {free} B of {capacity} B"
            ),
            Error::InvalidDevice { device, count } => {
                write!(f, "invalid device id {device} (node has {count} devices)")
            }
            Error::InvalidPointer { device, alloc_id } => {
                write!(f, "invalid device pointer: device {device}, allocation {alloc_id}")
            }
            Error::OutOfBounds { offset, len, size } => write!(
                f,
                "device buffer access out of bounds: offset {offset} + len {len} > size {size}"
            ),
            Error::Ipc(msg) => write!(f, "ipc error: {msg}"),
            Error::Layout(msg) => write!(f, "layout error: {msg}"),
            Error::Solver(msg) => write!(f, "solver error: {msg}"),
            Error::NotPositiveDefinite { minor } => write!(
                f,
                "matrix is not positive definite: leading minor {minor} is not positive"
            ),
            Error::NoConvergence { index, iters } => write!(
                f,
                "eigensolver failed to converge at eigenvalue {index} after {iters} iterations"
            ),
            Error::RefineStalled { iters, residual, tol } => write!(
                f,
                "iterative refinement stalled after {iters} iterations: residual {residual:.3e} > tol {tol:.3e}"
            ),
            Error::Shape(msg) => write!(f, "shape mismatch: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::NoLiveWorkers { total } => {
                write!(f, "no live workers left (all {total} dead); request cannot be served")
            }
            Error::Xla(e) => write!(f, "xla: {e}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Helper for shape errors.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }

    /// Helper for layout errors.
    pub fn layout(msg: impl Into<String>) -> Self {
        Error::Layout(msg.into())
    }

    /// Helper for solver errors.
    pub fn solver(msg: impl Into<String>) -> Self {
        Error::Solver(msg.into())
    }

    /// Helper for runtime errors.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }

    /// Helper for config errors.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }

    /// Helper for ipc errors.
    pub fn ipc(msg: impl Into<String>) -> Self {
        Error::Ipc(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_oom() {
        let e = Error::DeviceOom { device: 3, requested: 100, free: 10, capacity: 50 };
        let s = format!("{e}");
        assert!(s.contains("device 3"));
        assert!(s.contains("requested 100"));
    }

    #[test]
    fn helpers_construct_variants() {
        assert!(matches!(Error::shape("x"), Error::Shape(_)));
        assert!(matches!(Error::layout("x"), Error::Layout(_)));
        assert!(matches!(Error::solver("x"), Error::Solver(_)));
        assert!(matches!(Error::runtime("x"), Error::Runtime(_)));
        assert!(matches!(Error::config("x"), Error::Config(_)));
        assert!(matches!(Error::ipc("x"), Error::Ipc(_)));
    }

    #[test]
    fn io_and_xla_errors_convert() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(format!("{e}").contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
