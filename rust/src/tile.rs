//! Distributed matrix storage: one contiguous panel per device.
//!
//! A [`DistMatrix`] is an `rows × n` matrix spread over the node's
//! devices according to a [`LayoutKind`] handle:
//!
//! * **columnar** kinds ([`ContiguousBlock`], [`BlockCyclic1D`]):
//!   device `d` holds `rows × local_cols(d)` scalars in column-major
//!   order — the storage contract cuSOLVERMg imposes (`array_d_A`: one
//!   pointer per device, columns contiguous);
//! * **tile-grid** kinds ([`BlockCyclic2D`], [`ContiguousGrid2D`]):
//!   device `(r, c)` holds `local_rows × local_cols` scalars in
//!   tile-major order (tile columns left to right, tiles top to bottom
//!   within a tile column, each tile contiguous column-major). A
//!   `P = 1` grid of full-height tiles stores **bitwise identically**
//!   to the columnar contract, which is what lets the 1D solvers run
//!   unchanged on such handles via [`LayoutKind::compat_1d`].

use crate::device::{DevPtr, SimNode};
use crate::error::{Error, Result};
use crate::layout::{
    BlockCyclic1D, BlockCyclic2D, ColumnLayout, ContiguousBlock, ContiguousGrid2D, MatrixLayout,
};
use crate::linalg::Matrix;
use crate::scalar::Scalar;

/// The concrete layouts a distributed matrix can be in.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum LayoutKind {
    /// JAX shard_map input layout: contiguous per-device column blocks.
    Contiguous(ContiguousBlock),
    /// cuSOLVERMg compute layout: 1D block-cyclic column tiles.
    BlockCyclic(BlockCyclic1D),
    /// 2D block-cyclic tile grid (the paper's future-work layout).
    Grid(BlockCyclic2D),
    /// 2D-mesh shard input layout: blocked tile grid.
    GridContig(ContiguousGrid2D),
}

/// Historical name of [`LayoutKind`] from before the 2D generalization;
/// existing callers construct `Layout1D::Contiguous(..)` etc. through
/// this alias.
pub type Layout1D = LayoutKind;

/// One contiguous piece of a global column inside a device panel (a
/// tile-row segment; columnar layouts have exactly one per column).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct ColSeg {
    /// First global row covered.
    pub r0: usize,
    /// Rows covered.
    pub len: usize,
    /// Owning device.
    pub dev: usize,
    /// Offset (in scalars) of the segment start within the panel.
    pub elem_off: usize,
}

impl LayoutKind {
    /// Total columns distributed.
    pub fn n_cols(&self) -> usize {
        match self {
            LayoutKind::Contiguous(l) => l.n_cols(),
            LayoutKind::BlockCyclic(l) => l.n_cols(),
            LayoutKind::Grid(l) => l.shape().1,
            LayoutKind::GridContig(l) => l.shape().1,
        }
    }

    /// Devices spanned by the layout.
    pub fn num_devices(&self) -> usize {
        match self {
            LayoutKind::Contiguous(l) => ColumnLayout::num_devices(l),
            LayoutKind::BlockCyclic(l) => ColumnLayout::num_devices(l),
            LayoutKind::Grid(l) => MatrixLayout::num_devices(l),
            LayoutKind::GridContig(l) => MatrixLayout::num_devices(l),
        }
    }

    /// Borrow the 1D column-layout view, for columnar kinds only.
    pub fn column(&self) -> Option<&dyn ColumnLayout> {
        match self {
            LayoutKind::Contiguous(l) => Some(l),
            LayoutKind::BlockCyclic(l) => Some(l),
            _ => None,
        }
    }

    /// Borrow the tile-grid view, for grid kinds only.
    pub fn matrix_layout(&self) -> Option<&dyn MatrixLayout> {
        match self {
            LayoutKind::Grid(l) => Some(l),
            LayoutKind::GridContig(l) => Some(l),
            _ => None,
        }
    }

    /// The 1D block-cyclic descriptor, if that is the current layout.
    pub fn as_block_cyclic(&self) -> Option<&BlockCyclic1D> {
        match self {
            LayoutKind::BlockCyclic(l) => Some(l),
            _ => None,
        }
    }

    /// The 2D block-cyclic descriptor, if that is the current layout.
    pub fn grid2d(&self) -> Option<&BlockCyclic2D> {
        match self {
            LayoutKind::Grid(l) => Some(l),
            _ => None,
        }
    }

    /// The `(P, Q)` process-grid shape of the layout: `(1, ndev)` for
    /// the columnar kinds (they are `1 × Q` deals), the grid shape for
    /// tile-grid kinds — what the serving fronts report per solve.
    pub fn grid_shape(&self) -> (usize, usize) {
        match self {
            LayoutKind::Contiguous(_) | LayoutKind::BlockCyclic(_) => (1, self.num_devices()),
            LayoutKind::Grid(l) => l.grid(),
            LayoutKind::GridContig(l) => l.grid(),
        }
    }

    /// The 1D block-cyclic *compatibility view* for a matrix with
    /// `rows` rows: the layout the 1D solvers (`potrf`/`potrs`/`potri`
    /// and `syevd`'s 1D path) run on. Covers the native 1D kind and any
    /// `P = 1` grid of full-height tiles — whose storage is bitwise
    /// identical, so the solvers need no code changes.
    pub fn compat_1d(&self, rows: usize) -> Option<BlockCyclic1D> {
        match self {
            LayoutKind::BlockCyclic(l) => Some(*l),
            LayoutKind::Grid(g) if g.rows() == rows => g.as_column_layout(),
            _ => None,
        }
    }

    /// Scalars stored on device `d` for a matrix with `rows` rows.
    pub fn local_elems(&self, rows: usize, d: usize) -> usize {
        match self {
            LayoutKind::Contiguous(l) => rows * l.local_cols(d),
            LayoutKind::BlockCyclic(l) => rows * l.local_cols(d),
            LayoutKind::Grid(l) => l.local_elems(d),
            LayoutKind::GridContig(l) => l.local_elems(d),
        }
    }

    /// The contiguous panel segments of global column `j`, in ascending
    /// row order. Columnar layouts yield one full-height segment; grid
    /// layouts one segment per tile row (a tile's column is contiguous
    /// inside the tile block).
    pub fn col_segments(&self, rows: usize, j: usize) -> Vec<ColSeg> {
        match self {
            LayoutKind::Contiguous(_) | LayoutKind::BlockCyclic(_) => {
                let l = self.column().expect("columnar kind");
                let (dev, loc) = l.place(j);
                vec![ColSeg { r0: 0, len: rows, dev, elem_off: loc * rows }]
            }
            LayoutKind::Grid(_) | LayoutKind::GridContig(_) => {
                let g = self.matrix_layout().expect("grid kind");
                let rd = g.row_dim();
                let mut segs = Vec::with_capacity(rd.num_tiles());
                for tr in 0..rd.num_tiles() {
                    let (dev, off) = g.place_elem(rd.tile_start(tr), j);
                    segs.push(ColSeg {
                        r0: rd.tile_start(tr),
                        len: rd.tile_len(tr),
                        dev,
                        elem_off: off,
                    });
                }
                segs
            }
        }
    }

    /// Whether the layout's row extent matches a `rows`-high matrix
    /// (columnar kinds carry no row extent and always match).
    pub fn rows_match(&self, rows: usize) -> bool {
        match self {
            LayoutKind::Contiguous(_) | LayoutKind::BlockCyclic(_) => true,
            LayoutKind::Grid(l) => l.shape().0 == rows,
            LayoutKind::GridContig(l) => l.shape().0 == rows,
        }
    }
}

/// Device `d`'s panel contents for `host` under `layout`, in storage
/// order — the shard a worker process stages **locally** in MPMD mode
/// (each worker builds and uploads only its own panel; the single
/// caller assembles the pointers via [`DistMatrix::from_panels`]).
/// Layout-generic: columnar kinds yield the 1D column panels, grid
/// kinds the tile-major 2D shards — which is what lets MPMD workers
/// stage and IPC-export 2D tiles for grid-native solves with the same
/// code path. [`DistMatrix::scatter`] uses the same function, so
/// worker-staged panels are bitwise identical to single-caller
/// scatters.
pub fn build_panel<S: Scalar>(
    layout: &LayoutKind,
    rows: usize,
    host: &Matrix<S>,
    d: usize,
) -> Vec<S> {
    let len = layout.local_elems(rows, d);
    let mut panel = Vec::with_capacity(len);
    match layout {
        LayoutKind::Contiguous(_) | LayoutKind::BlockCyclic(_) => {
            let l = layout.column().expect("columnar kind");
            for loc in 0..l.local_cols(d) {
                panel.extend_from_slice(host.col(l.global_index(d, loc)));
            }
        }
        LayoutKind::Grid(_) | LayoutKind::GridContig(_) => {
            let g = layout.matrix_layout().expect("grid kind");
            for ord in 0..g.tiles_on(d) {
                let (tr, tc) = g.tile_at(d, ord);
                let (h, w) = g.tile_dims(tr, tc);
                let (r0, c0) = (g.row_dim().tile_start(tr), g.col_dim().tile_start(tc));
                for jj in 0..w {
                    let col = host.col(c0 + jj);
                    panel.extend_from_slice(&col[r0..r0 + h]);
                }
            }
        }
    }
    debug_assert_eq!(panel.len(), len);
    panel
}

/// A matrix distributed over the simulated node.
pub struct DistMatrix<S: Scalar> {
    node: SimNode,
    rows: usize,
    layout: LayoutKind,
    panels: Vec<DevPtr>,
    _marker: std::marker::PhantomData<S>,
}

impl<S: Scalar> DistMatrix<S> {
    /// Allocate (zero-initialized) panels for `rows × layout.n_cols()`.
    pub fn alloc(node: &SimNode, rows: usize, layout: LayoutKind) -> Result<Self> {
        if layout.num_devices() != node.num_devices() {
            return Err(Error::layout(format!(
                "layout spans {} devices but node has {}",
                layout.num_devices(),
                node.num_devices()
            )));
        }
        if !layout.rows_match(rows) {
            return Err(Error::shape(format!(
                "grid layout distributes a different row count than the matrix's {rows}"
            )));
        }
        let mut panels = Vec::with_capacity(node.num_devices());
        for d in 0..node.num_devices() {
            let len = layout.local_elems(rows, d);
            // Always allocate (possibly zero-length) so indices line up.
            let ptr = node.alloc_scalars::<S>(d, len)?;
            panels.push(ptr);
        }
        Ok(DistMatrix { node: node.clone(), rows, layout, panels, _marker: std::marker::PhantomData })
    }

    /// Assemble a distributed matrix over panels that were allocated
    /// and staged **elsewhere** — the single-caller step of the MPMD
    /// pipeline: each worker process stages its own shard
    /// ([`build_panel`]) and exports its pointer; the caller opens the
    /// foreign handles and views them as one matrix. The caller does
    /// **not** own the panels: drop them back to their owners with
    /// [`DistMatrix::into_panels`] instead of letting `Drop` free
    /// worker-owned memory.
    pub fn from_panels(
        node: &SimNode,
        rows: usize,
        layout: LayoutKind,
        panels: Vec<DevPtr>,
    ) -> Result<Self> {
        if layout.num_devices() != node.num_devices() {
            return Err(Error::layout(format!(
                "layout spans {} devices but node has {}",
                layout.num_devices(),
                node.num_devices()
            )));
        }
        if panels.len() != node.num_devices() {
            return Err(Error::layout(format!(
                "{} panels for a {}-device node",
                panels.len(),
                node.num_devices()
            )));
        }
        if !layout.rows_match(rows) {
            return Err(Error::shape(format!(
                "grid layout distributes a different row count than the matrix's {rows}"
            )));
        }
        for (d, p) in panels.iter().enumerate() {
            if p.device != d {
                return Err(Error::layout(format!(
                    "panel {d} points at device {} — pointers must be device-ordered",
                    p.device
                )));
            }
        }
        Ok(DistMatrix { node: node.clone(), rows, layout, panels, _marker: std::marker::PhantomData })
    }

    /// Release the panel pointers **without freeing them** — the
    /// counterpart of [`DistMatrix::from_panels`] for panels owned by
    /// worker processes. After this the matrix is empty and its `Drop`
    /// is a no-op.
    pub fn into_panels(mut self) -> Vec<DevPtr> {
        std::mem::take(&mut self.panels)
    }

    /// Scatter a host matrix onto the devices in the given layout
    /// (the `jax.device_put` analogue).
    pub fn scatter(node: &SimNode, host: &Matrix<S>, layout: LayoutKind) -> Result<Self> {
        if host.cols() != layout.n_cols() {
            return Err(Error::shape(format!(
                "matrix has {} cols but layout distributes {}",
                host.cols(),
                layout.n_cols()
            )));
        }
        let dm = Self::alloc(node, host.rows(), layout)?;
        // Build each device's panel host-side, then one H2D write per device.
        for d in 0..node.num_devices() {
            let panel = dm.build_panel_from(host, d);
            if panel.is_empty() {
                continue;
            }
            node.write_slice(dm.panels[d], 0, &panel)?;
            node.charge_h2d(d, std::mem::size_of_val(panel.as_slice()))?;
        }
        Ok(dm)
    }

    /// Gather back to a host matrix (the `jax.device_get` analogue).
    pub fn gather(&self) -> Result<Matrix<S>> {
        let mut host = Matrix::<S>::zeros(self.rows, self.layout.n_cols());
        for d in 0..self.node.num_devices() {
            let len = self.layout.local_elems(self.rows, d);
            if len == 0 {
                continue;
            }
            let mut panel = vec![S::zero(); len];
            self.node.read_slice(self.panels[d], 0, &mut panel)?;
            self.node.charge_h2d(d, std::mem::size_of_val(panel.as_slice()))?;
            self.spread_panel_into(&mut host, d, &panel);
        }
        Ok(host)
    }

    /// Device `d`'s panel contents for `host`, in storage order.
    fn build_panel_from(&self, host: &Matrix<S>, d: usize) -> Vec<S> {
        build_panel(&self.layout, self.rows, host, d)
    }

    /// Inverse of [`DistMatrix::build_panel_from`].
    fn spread_panel_into(&self, host: &mut Matrix<S>, d: usize, panel: &[S]) {
        match &self.layout {
            LayoutKind::Contiguous(_) | LayoutKind::BlockCyclic(_) => {
                let l = self.layout.column().expect("columnar kind");
                for loc in 0..l.local_cols(d) {
                    let g = l.global_index(d, loc);
                    host.col_mut(g)
                        .copy_from_slice(&panel[loc * self.rows..(loc + 1) * self.rows]);
                }
            }
            LayoutKind::Grid(_) | LayoutKind::GridContig(_) => {
                let g = self.layout.matrix_layout().expect("grid kind");
                let mut off = 0usize;
                for ord in 0..g.tiles_on(d) {
                    let (tr, tc) = g.tile_at(d, ord);
                    let (h, w) = g.tile_dims(tr, tc);
                    let (r0, c0) = (g.row_dim().tile_start(tr), g.col_dim().tile_start(tc));
                    for jj in 0..w {
                        host.col_mut(c0 + jj)[r0..r0 + h].copy_from_slice(&panel[off..off + h]);
                        off += h;
                    }
                }
            }
        }
    }

    /// Host mirror of the whole matrix *without* the H2D timing charge —
    /// the staging path distributed kernels use (like
    /// [`DistMatrix::read_block`], charges are issued explicitly by the
    /// solver's cost accounting; see `device::SimNode::write_slice`).
    pub fn mirror_host(&self) -> Result<Matrix<S>> {
        let mut host = Matrix::<S>::zeros(self.rows, self.layout.n_cols());
        for d in 0..self.node.num_devices() {
            let len = self.layout.local_elems(self.rows, d);
            if len == 0 {
                continue;
            }
            let mut panel = vec![S::zero(); len];
            self.node.read_slice(self.panels[d], 0, &mut panel)?;
            self.spread_panel_into(&mut host, d, &panel);
        }
        Ok(host)
    }

    /// Write a full host mirror back to the device panels (the inverse
    /// of [`DistMatrix::mirror_host`]; no timing charge).
    pub fn write_back_host(&self, host: &Matrix<S>) -> Result<()> {
        if host.rows() != self.rows || host.cols() != self.layout.n_cols() {
            return Err(Error::shape(format!(
                "mirror is {}x{} but the distributed matrix is {}x{}",
                host.rows(),
                host.cols(),
                self.rows,
                self.layout.n_cols()
            )));
        }
        for d in 0..self.node.num_devices() {
            let panel = self.build_panel_from(host, d);
            if panel.is_empty() {
                continue;
            }
            self.node.write_slice(self.panels[d], 0, &panel)?;
        }
        Ok(())
    }

    /// Panel height (matrix rows).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Total columns.
    pub fn cols(&self) -> usize {
        self.layout.n_cols()
    }

    /// Current layout descriptor.
    pub fn layout(&self) -> &LayoutKind {
        &self.layout
    }

    /// The node this matrix lives on.
    pub fn node(&self) -> &SimNode {
        &self.node
    }

    /// Per-device base pointers — what the workers publish through
    /// `ipc` and the single caller hands to the solver.
    pub fn panels(&self) -> &[DevPtr] {
        &self.panels
    }

    /// Byte offset of local column `loc` within its device panel
    /// (columnar storage).
    #[inline]
    pub fn col_byte_offset(&self, loc: usize) -> usize {
        loc * self.rows * std::mem::size_of::<S>()
    }

    /// Bytes per full-height column.
    #[inline]
    pub fn col_bytes(&self) -> usize {
        self.rows * std::mem::size_of::<S>()
    }

    /// Replace the layout descriptor (used by the redistributor after
    /// it has physically permuted the storage).
    pub(crate) fn set_layout(&mut self, layout: LayoutKind) {
        self.layout = layout;
    }

    /// Swap the panel pointers (used by out-of-place redistribution).
    pub(crate) fn replace_panels(&mut self, panels: Vec<DevPtr>, layout: LayoutKind) -> Result<()> {
        for &old in &self.panels {
            self.node.free(old)?;
        }
        self.panels = panels;
        self.layout = layout;
        Ok(())
    }

    /// Read a host copy of a row-range × column-range of one device's
    /// panel: `rows r0..r0+nr` of local columns `c0..c0+nc`.
    /// This is the staging path tile kernels use to feed XLA
    /// executables. Valid for columnar storage (including `P = 1` grids,
    /// whose storage is bitwise columnar).
    pub fn read_block(&self, dev: usize, r0: usize, nr: usize, c0: usize, nc: usize) -> Result<Matrix<S>> {
        let mut out = Matrix::<S>::zeros(nr, nc);
        for j in 0..nc {
            let off = (c0 + j) * self.rows + r0;
            let col = &mut out.col_mut(j)[..nr];
            self.node.read_slice(self.panels[dev], off, col)?;
        }
        Ok(out)
    }

    /// Write a host block back into one device's panel (columnar
    /// storage; see [`DistMatrix::read_block`]).
    pub fn write_block(&self, dev: usize, r0: usize, c0: usize, block: &Matrix<S>) -> Result<()> {
        for j in 0..block.cols() {
            let off = (c0 + j) * self.rows + r0;
            self.node.write_slice(self.panels[dev], off, &block.col(j)[..block.rows()])?;
        }
        Ok(())
    }

    /// Free the device allocations. (Also called on drop; explicit form
    /// propagates errors.)
    pub fn free(mut self) -> Result<()> {
        let panels = std::mem::take(&mut self.panels);
        for p in panels {
            self.node.free(p)?;
        }
        Ok(())
    }
}

impl<S: Scalar> Drop for DistMatrix<S> {
    fn drop(&mut self) {
        for p in self.panels.drain(..) {
            let _ = self.node.free(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalar::c64;

    fn node4() -> SimNode {
        SimNode::new_uniform(4, 1 << 24)
    }

    #[test]
    fn scatter_gather_contiguous_roundtrip() {
        let node = node4();
        let a = Matrix::<f64>::random(12, 16, 1);
        let layout = Layout1D::Contiguous(ContiguousBlock::new(16, 4).unwrap());
        let dm = DistMatrix::scatter(&node, &a, layout).unwrap();
        let b = dm.gather().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn scatter_gather_block_cyclic_roundtrip() {
        let node = node4();
        let a = Matrix::<c64>::random(10, 14, 2); // ragged: 14 cols, T=3, 4 devs
        let layout = Layout1D::BlockCyclic(BlockCyclic1D::new(14, 3, 4).unwrap());
        let dm = DistMatrix::scatter(&node, &a, layout).unwrap();
        let b = dm.gather().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn scatter_gather_grid_roundtrip() {
        let node = node4();
        // Ragged both ways: 10×14 in 4×3 tiles on a 2×2 grid.
        let a = Matrix::<f64>::random(10, 14, 3);
        let layout = LayoutKind::Grid(BlockCyclic2D::new(10, 14, 4, 3, 2, 2).unwrap());
        let dm = DistMatrix::scatter(&node, &a, layout).unwrap();
        assert_eq!(dm.gather().unwrap(), a);
        // And the blocked grid deal.
        let layout2 = LayoutKind::GridContig(ContiguousGrid2D::new(10, 14, 4, 3, 2, 2).unwrap());
        let dm2 = DistMatrix::scatter(&node, &a, layout2).unwrap();
        assert_eq!(dm2.gather().unwrap(), a);
    }

    #[test]
    fn grid_p1_storage_is_bitwise_columnar() {
        // A P=1 grid of full-height tiles must produce panels bitwise
        // identical to the 1D block-cyclic layout's.
        let node = node4();
        let (m, n, t) = (6, 12, 2);
        let a = Matrix::<f32>::random(m, n, 4);
        let l1 = LayoutKind::BlockCyclic(BlockCyclic1D::new(n, t, 4).unwrap());
        let l2 = LayoutKind::Grid(BlockCyclic2D::new(m, n, m, t, 1, 4).unwrap());
        let d1 = DistMatrix::scatter(&node, &a, l1).unwrap();
        let d2 = DistMatrix::scatter(&node, &a, l2).unwrap();
        for d in 0..4 {
            let len = l1.local_elems(m, d);
            assert_eq!(len, l2.local_elems(m, d));
            let mut p1 = vec![0.0f32; len];
            let mut p2 = vec![0.0f32; len];
            node.read_slice(d1.panels()[d], 0, &mut p1).unwrap();
            node.read_slice(d2.panels()[d], 0, &mut p2).unwrap();
            assert_eq!(p1, p2, "panel {d} differs between 1D and P=1 grid storage");
        }
        // And the compatibility view reproduces the 1D descriptor.
        assert_eq!(l2.compat_1d(m), Some(BlockCyclic1D::new(n, t, 4).unwrap()));
    }

    #[test]
    fn mirror_and_write_back_roundtrip() {
        let node = node4();
        let a = Matrix::<f64>::random(9, 9, 5);
        let layout = LayoutKind::Grid(BlockCyclic2D::new(9, 9, 2, 3, 2, 2).unwrap());
        let dm = DistMatrix::scatter(&node, &a, layout).unwrap();
        let m = dm.mirror_host().unwrap();
        assert_eq!(m, a);
        let b = Matrix::<f64>::random(9, 9, 6);
        dm.write_back_host(&b).unwrap();
        assert_eq!(dm.gather().unwrap(), b);
        assert!(dm.write_back_host(&Matrix::<f64>::zeros(3, 3)).is_err());
    }

    #[test]
    fn grid_shape_reports_process_grids() {
        assert_eq!(
            LayoutKind::BlockCyclic(BlockCyclic1D::new(12, 3, 4).unwrap()).grid_shape(),
            (1, 4)
        );
        assert_eq!(
            LayoutKind::Grid(BlockCyclic2D::new(12, 12, 3, 3, 2, 2).unwrap()).grid_shape(),
            (2, 2)
        );
        assert_eq!(
            LayoutKind::GridContig(ContiguousGrid2D::new(12, 12, 3, 3, 4, 1).unwrap()).grid_shape(),
            (4, 1)
        );
    }

    #[test]
    fn col_segments_cover_each_column() {
        let rows = 10;
        let lays = [
            LayoutKind::BlockCyclic(BlockCyclic1D::new(14, 3, 4).unwrap()),
            LayoutKind::Grid(BlockCyclic2D::new(rows, 14, 4, 3, 2, 2).unwrap()),
            LayoutKind::GridContig(ContiguousGrid2D::new(rows, 14, 4, 3, 2, 2).unwrap()),
        ];
        for lay in &lays {
            for j in 0..14 {
                let segs = lay.col_segments(rows, j);
                let mut next = 0usize;
                for s in &segs {
                    assert_eq!(s.r0, next, "segments must tile the column in order");
                    assert!(s.len > 0);
                    next += s.len;
                }
                assert_eq!(next, rows);
            }
        }
    }

    #[test]
    fn block_read_write() {
        let node = node4();
        let a = Matrix::<f32>::random(8, 8, 3);
        let layout = Layout1D::Contiguous(ContiguousBlock::new(8, 4).unwrap());
        let dm = DistMatrix::scatter(&node, &a, layout).unwrap();
        // Device 1 owns global cols 2,3 (8/4 = 2 each).
        let blk = dm.read_block(1, 2, 4, 0, 2).unwrap();
        assert_eq!(blk[(0, 0)], a[(2, 2)]);
        assert_eq!(blk[(3, 1)], a[(5, 3)]);
        // Overwrite and check.
        let z = Matrix::<f32>::ones(4, 2);
        dm.write_block(1, 2, 0, &z).unwrap();
        let b = dm.gather().unwrap();
        assert_eq!(b[(2, 2)], 1.0);
        assert_eq!(b[(5, 3)], 1.0);
        assert_eq!(b[(1, 2)], a[(1, 2)]); // untouched rows intact
    }

    #[test]
    fn from_panels_assembles_worker_staged_shards() {
        // The MPMD staging pipeline: each "worker" builds + uploads its
        // own panel; the assembled view gathers bitwise identically to
        // a single-caller scatter, and into_panels leaves ownership
        // with the workers (nothing freed).
        let node = node4();
        let a = Matrix::<f64>::random(10, 14, 7);
        let layout = Layout1D::BlockCyclic(BlockCyclic1D::new(14, 3, 4).unwrap());
        let mut ptrs = Vec::new();
        for d in 0..4 {
            let panel = build_panel(&layout, 10, &a, d);
            let ptr = node.alloc_scalars::<f64>(d, panel.len()).unwrap();
            if !panel.is_empty() {
                node.write_slice(ptr, 0, &panel).unwrap();
            }
            ptrs.push(ptr);
        }
        let dm = DistMatrix::<f64>::from_panels(&node, 10, layout, ptrs.clone()).unwrap();
        assert_eq!(dm.gather().unwrap(), a);
        let back = dm.into_panels();
        assert_eq!(back, ptrs);
        // Nothing was freed: the allocations are still live.
        for p in &back {
            assert!(node.ptr_exists(*p));
            node.free(*p).unwrap();
        }
        // Validation: panel count and device order are enforced.
        assert!(DistMatrix::<f64>::from_panels(&node, 10, layout, vec![]).is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let node = node4();
        let a = Matrix::<f64>::zeros(4, 5);
        let layout = Layout1D::Contiguous(ContiguousBlock::new(6, 4).unwrap());
        assert!(DistMatrix::scatter(&node, &a, layout).is_err());
        // Grid row extent must match the matrix height.
        let g = LayoutKind::Grid(BlockCyclic2D::new(8, 5, 2, 2, 2, 2).unwrap());
        assert!(DistMatrix::scatter(&node, &a, g).is_err());
    }

    #[test]
    fn free_releases_vram() {
        let node = SimNode::new_uniform(2, 4096);
        let a = Matrix::<f64>::zeros(16, 16); // 8 cols × 16 rows × 8 B = 1024 B per device
        let layout = Layout1D::Contiguous(ContiguousBlock::new(16, 2).unwrap());
        let dm = DistMatrix::scatter(&node, &a, layout).unwrap();
        assert_eq!(node.memory_reports()[0].used, 1024);
        dm.free().unwrap();
        assert_eq!(node.memory_reports()[0].used, 0);
    }

    #[test]
    fn drop_also_frees() {
        let node = SimNode::new_uniform(1, 4096);
        {
            let layout = Layout1D::Contiguous(ContiguousBlock::new(4, 1).unwrap());
            let _dm = DistMatrix::<f64>::alloc(&node, 4, layout).unwrap();
            assert!(node.memory_reports()[0].used > 0);
        }
        assert_eq!(node.memory_reports()[0].used, 0);
    }

    #[test]
    fn oom_on_scatter_too_big() {
        let node = SimNode::new_uniform(1, 64);
        let a = Matrix::<f64>::zeros(8, 8);
        let layout = Layout1D::Contiguous(ContiguousBlock::new(8, 1).unwrap());
        assert!(matches!(
            DistMatrix::scatter(&node, &a, layout),
            Err(Error::DeviceOom { .. })
        ));
    }
}
