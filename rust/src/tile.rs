//! Distributed matrix storage: one contiguous column panel per device.
//!
//! A [`DistMatrix`] is an `rows × n` matrix whose columns are spread
//! over the node's devices according to a [`ColumnLayout`]. Device `d`
//! holds a single allocation of `rows × local_cols(d)` scalars in
//! column-major order — the same storage contract cuSOLVERMg imposes
//! (`array_d_A`: one pointer per device, columns contiguous).

use crate::device::{DevPtr, SimNode};
use crate::error::{Error, Result};
use crate::layout::{BlockCyclic1D, ColumnLayout, ContiguousBlock};
use crate::linalg::Matrix;
use crate::scalar::Scalar;

/// The concrete 1D layouts a distributed matrix can be in.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Layout1D {
    /// JAX shard_map input layout: contiguous per-device blocks.
    Contiguous(ContiguousBlock),
    /// cuSOLVERMg compute layout: 1D block-cyclic tiles.
    BlockCyclic(BlockCyclic1D),
}

impl Layout1D {
    /// Borrow as the layout trait object.
    pub fn as_layout(&self) -> &dyn ColumnLayout {
        match self {
            Layout1D::Contiguous(l) => l,
            Layout1D::BlockCyclic(l) => l,
        }
    }

    /// The block-cyclic descriptor, if that is the current layout.
    pub fn as_block_cyclic(&self) -> Option<&BlockCyclic1D> {
        match self {
            Layout1D::BlockCyclic(l) => Some(l),
            Layout1D::Contiguous(_) => None,
        }
    }
}

/// A matrix distributed column-wise over the simulated node.
pub struct DistMatrix<S: Scalar> {
    node: SimNode,
    rows: usize,
    layout: Layout1D,
    panels: Vec<DevPtr>,
    _marker: std::marker::PhantomData<S>,
}

impl<S: Scalar> DistMatrix<S> {
    /// Allocate (zero-initialized) panels for `rows × layout.n_cols()`.
    pub fn alloc(node: &SimNode, rows: usize, layout: Layout1D) -> Result<Self> {
        let l = layout.as_layout();
        if l.num_devices() != node.num_devices() {
            return Err(Error::layout(format!(
                "layout spans {} devices but node has {}",
                l.num_devices(),
                node.num_devices()
            )));
        }
        let mut panels = Vec::with_capacity(node.num_devices());
        for d in 0..node.num_devices() {
            let len = rows * l.local_cols(d);
            // Always allocate (possibly zero-length) so indices line up.
            let ptr = node.alloc_scalars::<S>(d, len)?;
            panels.push(ptr);
        }
        Ok(DistMatrix { node: node.clone(), rows, layout, panels, _marker: std::marker::PhantomData })
    }

    /// Scatter a host matrix onto the devices in the given layout
    /// (the `jax.device_put` analogue).
    pub fn scatter(node: &SimNode, host: &Matrix<S>, layout: Layout1D) -> Result<Self> {
        let l = layout.as_layout();
        if host.cols() != l.n_cols() {
            return Err(Error::shape(format!(
                "matrix has {} cols but layout distributes {}",
                host.cols(),
                l.n_cols()
            )));
        }
        let dm = Self::alloc(node, host.rows(), layout)?;
        // Build each device's panel host-side, then one H2D write per device.
        for d in 0..node.num_devices() {
            let lc = l.local_cols(d);
            if lc == 0 {
                continue;
            }
            let mut panel = Vec::with_capacity(dm.rows * lc);
            for loc in 0..lc {
                let g = l.global_index(d, loc);
                panel.extend_from_slice(host.col(g));
            }
            node.write_slice(dm.panels[d], 0, &panel)?;
            node.charge_h2d(d, panel.len() * std::mem::size_of::<S>())?;
        }
        Ok(dm)
    }

    /// Gather back to a host matrix (the `jax.device_get` analogue).
    pub fn gather(&self) -> Result<Matrix<S>> {
        let l = self.layout.as_layout();
        let mut host = Matrix::<S>::zeros(self.rows, l.n_cols());
        for d in 0..self.node.num_devices() {
            let lc = l.local_cols(d);
            if lc == 0 {
                continue;
            }
            let mut panel = vec![S::zero(); self.rows * lc];
            self.node.read_slice(self.panels[d], 0, &mut panel)?;
            self.node.charge_h2d(d, panel.len() * std::mem::size_of::<S>())?;
            for loc in 0..lc {
                let g = l.global_index(d, loc);
                host.col_mut(g).copy_from_slice(&panel[loc * self.rows..(loc + 1) * self.rows]);
            }
        }
        Ok(host)
    }

    /// Panel height (matrix rows).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Total columns.
    pub fn cols(&self) -> usize {
        self.layout.as_layout().n_cols()
    }

    /// Current layout descriptor.
    pub fn layout(&self) -> &Layout1D {
        &self.layout
    }

    /// The node this matrix lives on.
    pub fn node(&self) -> &SimNode {
        &self.node
    }

    /// Per-device base pointers — what the workers publish through
    /// `ipc` and the single caller hands to the solver.
    pub fn panels(&self) -> &[DevPtr] {
        &self.panels
    }

    /// Byte offset of local column `loc` within its device panel.
    #[inline]
    pub fn col_byte_offset(&self, loc: usize) -> usize {
        loc * self.rows * std::mem::size_of::<S>()
    }

    /// Bytes per column.
    #[inline]
    pub fn col_bytes(&self) -> usize {
        self.rows * std::mem::size_of::<S>()
    }

    /// Replace the layout descriptor (used by the redistributor after
    /// it has physically permuted the columns).
    pub(crate) fn set_layout(&mut self, layout: Layout1D) {
        self.layout = layout;
    }

    /// Swap the panel pointers (used by out-of-place redistribution).
    pub(crate) fn replace_panels(&mut self, panels: Vec<DevPtr>, layout: Layout1D) -> Result<()> {
        for &old in &self.panels {
            self.node.free(old)?;
        }
        self.panels = panels;
        self.layout = layout;
        Ok(())
    }

    /// Read a host copy of a row-range × column-range of one device's
    /// panel: `rows r0..r0+nr` of local columns `c0..c0+nc`.
    /// This is the staging path tile kernels use to feed XLA executables.
    pub fn read_block(&self, dev: usize, r0: usize, nr: usize, c0: usize, nc: usize) -> Result<Matrix<S>> {
        let mut out = Matrix::<S>::zeros(nr, nc);
        for j in 0..nc {
            let off = (c0 + j) * self.rows + r0;
            let col = &mut out.col_mut(j)[..nr];
            self.node.read_slice(self.panels[dev], off, col)?;
        }
        Ok(out)
    }

    /// Write a host block back into one device's panel.
    pub fn write_block(&self, dev: usize, r0: usize, c0: usize, block: &Matrix<S>) -> Result<()> {
        for j in 0..block.cols() {
            let off = (c0 + j) * self.rows + r0;
            self.node.write_slice(self.panels[dev], off, &block.col(j)[..block.rows()])?;
        }
        Ok(())
    }

    /// Free the device allocations. (Also called on drop; explicit form
    /// propagates errors.)
    pub fn free(mut self) -> Result<()> {
        let panels = std::mem::take(&mut self.panels);
        for p in panels {
            self.node.free(p)?;
        }
        Ok(())
    }
}

impl<S: Scalar> Drop for DistMatrix<S> {
    fn drop(&mut self) {
        for p in self.panels.drain(..) {
            let _ = self.node.free(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalar::c64;

    fn node4() -> SimNode {
        SimNode::new_uniform(4, 1 << 24)
    }

    #[test]
    fn scatter_gather_contiguous_roundtrip() {
        let node = node4();
        let a = Matrix::<f64>::random(12, 16, 1);
        let layout = Layout1D::Contiguous(ContiguousBlock::new(16, 4).unwrap());
        let dm = DistMatrix::scatter(&node, &a, layout).unwrap();
        let b = dm.gather().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn scatter_gather_block_cyclic_roundtrip() {
        let node = node4();
        let a = Matrix::<c64>::random(10, 14, 2); // ragged: 14 cols, T=3, 4 devs
        let layout = Layout1D::BlockCyclic(BlockCyclic1D::new(14, 3, 4).unwrap());
        let dm = DistMatrix::scatter(&node, &a, layout).unwrap();
        let b = dm.gather().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn block_read_write() {
        let node = node4();
        let a = Matrix::<f32>::random(8, 8, 3);
        let layout = Layout1D::Contiguous(ContiguousBlock::new(8, 4).unwrap());
        let dm = DistMatrix::scatter(&node, &a, layout).unwrap();
        // Device 1 owns global cols 2,3 (8/4 = 2 each).
        let blk = dm.read_block(1, 2, 4, 0, 2).unwrap();
        assert_eq!(blk[(0, 0)], a[(2, 2)]);
        assert_eq!(blk[(3, 1)], a[(5, 3)]);
        // Overwrite and check.
        let z = Matrix::<f32>::ones(4, 2);
        dm.write_block(1, 2, 0, &z).unwrap();
        let b = dm.gather().unwrap();
        assert_eq!(b[(2, 2)], 1.0);
        assert_eq!(b[(5, 3)], 1.0);
        assert_eq!(b[(1, 2)], a[(1, 2)]); // untouched rows intact
    }

    #[test]
    fn shape_mismatch_rejected() {
        let node = node4();
        let a = Matrix::<f64>::zeros(4, 5);
        let layout = Layout1D::Contiguous(ContiguousBlock::new(6, 4).unwrap());
        assert!(DistMatrix::scatter(&node, &a, layout).is_err());
    }

    #[test]
    fn free_releases_vram() {
        let node = SimNode::new_uniform(2, 4096);
        let a = Matrix::<f64>::zeros(16, 16); // 8 cols × 16 rows × 8 B = 1024 B per device
        let layout = Layout1D::Contiguous(ContiguousBlock::new(16, 2).unwrap());
        let dm = DistMatrix::scatter(&node, &a, layout).unwrap();
        assert_eq!(node.memory_reports()[0].used, 1024);
        dm.free().unwrap();
        assert_eq!(node.memory_reports()[0].used, 0);
    }

    #[test]
    fn drop_also_frees() {
        let node = SimNode::new_uniform(1, 4096);
        {
            let layout = Layout1D::Contiguous(ContiguousBlock::new(4, 1).unwrap());
            let _dm = DistMatrix::<f64>::alloc(&node, 4, layout).unwrap();
            assert!(node.memory_reports()[0].used > 0);
        }
        assert_eq!(node.memory_reports()[0].used, 0);
    }

    #[test]
    fn oom_on_scatter_too_big() {
        let node = SimNode::new_uniform(1, 64);
        let a = Matrix::<f64>::zeros(8, 8);
        let layout = Layout1D::Contiguous(ContiguousBlock::new(8, 1).unwrap());
        assert!(matches!(
            DistMatrix::scatter(&node, &a, layout),
            Err(Error::DeviceOom { .. })
        ));
    }
}
