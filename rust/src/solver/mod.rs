//! Distributed dense solvers over the block-cyclic layouts — the
//! cuSOLVERMg substrate itself (`potrf`/`potrs`/`potri`/`syevd`),
//! executing natively on **1D column** layouts *and* **2D `P × Q`
//! tile grids**.
//!
//! Each routine is a *coordinator-scheduled* blocked algorithm: tile
//! kernels run "on" the simulated device owning the tile (charging that
//! device's timeline via the cost model), panels move between devices
//! with peer copies, and the numerical payload of every tile kernel is
//! delegated to a [`TileKernels`] backend:
//!
//! * [`NativeKernels`] — pure-Rust reference compute (`crate::linalg`);
//! * [`crate::runtime::XlaKernels`] — the AOT-compiled XLA executables
//!   produced by the Python layers (Pallas GEMM + JAX panel ops), the
//!   production path: Python authored them, but only Rust runs them.
//!
//! The two backends are interchangeable and cross-checked in the test
//! suite, which is the correctness argument for the AOT path.
//!
//! ## 1D vs 2D execution
//!
//! Every solver dispatches on the handle's [`crate::tile::LayoutKind`]:
//!
//! | layout | path | collectives | numerics |
//! |---|---|---|---|
//! | 1D block-cyclic | columnar (the seed path) | devices-wide panel broadcasts (`O(n·T)` bytes from one owner per step) | reference |
//! | `P = 1` grid, full-height tiles | **same columnar path** via [`crate::tile::LayoutKind::compat_1d`] (storage is bitwise identical) | identical | bitwise = 1D, schedule included |
//! | `P > 1` grid | **grid-native**: panels split over `P` row blocks | per-row / per-column **ring collectives** ([`Ctx::charge_row_ring_broadcast`] / [`Ctx::charge_col_ring_broadcast`]): `O(n·T/P)` bytes per disjoint ring | bitwise = 1D (same kernel sequence; only ownership and the timeline change) |
//!
//! The grid-native paths are the execution model for the PR-2 layout
//! model: `potrf`'s trailing update becomes one fused local GEMM per
//! device per step (the ScaLAPACK shape), its panel `trsm` splits
//! across the `P` row owners of the diagonal's grid column, and the
//! broadcast volume drops from `O(n)` devices-wide to row/column
//! rings. Communication is tallied per axis in the `grid_row_bytes` /
//! `grid_col_bytes` metrics; [`GridComm`] holds the row/column
//! membership arithmetic.
//!
//! ## Scheduling: barrier vs lookahead pipelining
//!
//! Every routine supports two *timing* schedules over identical
//! numerics (results are bitwise independent of the schedule):
//!
//! * **Barrier** ([`PipelineConfig::barrier`], the [`Ctx::new`]
//!   default): each kernel/copy charge lands directly on the owning
//!   device's clock, serializing panel work, broadcasts and trailing
//!   updates per device — the seed behaviour, kept as the regression
//!   baseline.
//! * **Lookahead** ([`PipelineConfig::lookahead`], built by
//!   [`Ctx::pipelined`] / [`Ctx::with_pipeline`]): charges are issued
//!   onto per-device compute/panel/copy [`crate::device::Stream`]s with
//!   event dependencies. In `potrf_dist` the panel for step `k+1` is
//!   factored on the priority stream as soon as its tile column has
//!   absorbed step `k`'s update — up to `lookahead` steps ahead of the
//!   trailing-update frontier — while broadcasts ride the copy streams.
//!   `potrs`/`potri`/`syevd` reuse the same machinery through the
//!   [`Ctx::charge_gemm`]-family helpers, so their copies and kernels
//!   overlap too. The grid-native paths keep the same k-step panel
//!   lookahead (the panel frontier is gated per tile column, rings ride
//!   the copy streams) and lookahead still strictly beats barrier on
//!   `P > 1` grids. Makespans shrink accordingly; the golden-timeline
//!   tests in `rust/tests/golden_timeline.rs` pin the win — 1D
//!   (`potrf_timelines.txt`, `potrs_timelines.txt`) and 2×2-grid
//!   (`potrf2d_timelines.txt`) alike.
//!
//! ### Knobs
//!
//! * `PipelineConfig::lookahead(k)` — panel depth `k` (default
//!   [`DEFAULT_LOOKAHEAD`]); `k = 0` is the barrier schedule.
//! * [`Ctx::end_phase`] returns a [`PhaseReport`] with the phase's
//!   busy/span/utilization; aggregates flow into
//!   [`crate::metrics::Metrics`] (`overlap_busy_ns`/`overlap_span_ns`).
//!
//! ## Observability
//!
//! A context built with [`Ctx::with_trace`] emits request-scoped spans
//! (`crate::obs`) for every charge — kernels, p2p hops, broadcasts,
//! ring collectives, panel copies — and [`lift_timeline_spans`] turns a
//! pipelined routine's [`DeviceTimeline`] snapshot into per-
//! device×stream stage spans. Tracing is purely passive (span bounds
//! are read from the clocks/streams the cost model already advanced),
//! so enabling it changes no golden timeline by a single ns. See
//! `OBSERVABILITY.md` at the repo root for the span taxonomy and how
//! to load the exports in Perfetto.

mod kernels;
mod mixed;
mod potrf;
mod potri;
mod potrs;
mod schedule;
mod syevd;

pub use kernels::{NativeKernels, TileKernels};
pub use mixed::{
    demote_matrix, promote_matrix, solve_dist_prec, MixedCapable, MixedReport, MixedRun,
    Precision, RefineOptions, SolveOutcome, DEFAULT_REFINE_CAP, DEFAULT_REFINE_TOL,
};
pub use potrf::potrf_dist;
pub use potri::potri_dist;
pub use potrs::potrs_dist;
pub use schedule::{
    DeviceTimeline, GridComm, PhaseReport, PipelineConfig, PipelineTimeline, RingAxis,
    DEFAULT_LOOKAHEAD,
};
pub use syevd::syevd_dist;

use crate::costmodel::GpuCostModel;
use crate::device::{DevPtr, Event, LinkKind, SimNode};
use crate::obs::{SpanId, TraceId, Tracer};
use crate::scalar::Scalar;
use std::sync::Arc;

/// Which compute backend the solvers use for tile kernels.
#[derive(Clone)]
pub enum SolverBackend<S: Scalar> {
    /// Pure-Rust tile kernels (reference; always available).
    Native,
    /// AOT-compiled XLA executables loaded via PJRT.
    Xla(Arc<dyn TileKernels<S>>),
}

impl<S: Scalar> SolverBackend<S> {
    /// Resolve to a concrete kernel set.
    pub fn kernels(&self) -> Arc<dyn TileKernels<S>> {
        match self {
            SolverBackend::Native => Arc::new(NativeKernels),
            SolverBackend::Xla(k) => k.clone(),
        }
    }
}

impl<S: Scalar> std::fmt::Debug for SolverBackend<S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolverBackend::Native => f.write_str("SolverBackend::Native"),
            SolverBackend::Xla(_) => f.write_str("SolverBackend::Xla"),
        }
    }
}

/// Shared state threaded through the solver routines. Public so
/// integration tests, benches and examples can drive the distributed
/// solvers directly (the `JaxMg` front end wraps this for normal use).
pub struct Ctx<'a, S: Scalar> {
    pub node: &'a SimNode,
    pub model: &'a GpuCostModel,
    pub kernels: Arc<dyn TileKernels<S>>,
    /// The timing schedule (barrier or lookahead pipelining).
    pub pipeline: PipelineConfig,
    timeline: Option<Arc<PipelineTimeline>>,
    /// Cooperative-preemption hook: called at panel boundaries of the
    /// distributed factorizations ([`Ctx::preempt_point`]). The solve
    /// service installs it so a queued latency-sensitive solve can run
    /// between a large solve's panels instead of behind them.
    preempt: Option<Arc<dyn Fn() + Send + Sync>>,
    /// Request-scoped tracing context ([`Ctx::with_trace`]); `None`
    /// when tracing is off, so the charge helpers pay nothing.
    trace: Option<TraceCtx>,
    /// Price multi-island collectives with the naive flat arithmetic
    /// instead of the hierarchical ring-of-rings dispatch — the bench
    /// baseline ([`Ctx::with_flat_collectives`]). Irrelevant on a
    /// single-island node.
    flat_collectives: bool,
}

/// The (tracer, trace, root-span) triple a serving front hands a `Ctx`
/// so the solver's charges attach to the request's span tree.
struct TraceCtx {
    tracer: Arc<Tracer>,
    trace: TraceId,
    root: SpanId,
}

impl<'a, S: Scalar> Ctx<'a, S> {
    /// Barrier-scheduled context (the seed behaviour).
    pub fn new(node: &'a SimNode, model: &'a GpuCostModel, backend: &SolverBackend<S>) -> Self {
        Self::with_pipeline(node, model, backend, PipelineConfig::barrier())
    }

    /// Lookahead-pipelined context at the default depth.
    pub fn pipelined(
        node: &'a SimNode,
        model: &'a GpuCostModel,
        backend: &SolverBackend<S>,
    ) -> Self {
        Self::with_pipeline(node, model, backend, PipelineConfig::default())
    }

    /// Context with an explicit schedule.
    pub fn with_pipeline(
        node: &'a SimNode,
        model: &'a GpuCostModel,
        backend: &SolverBackend<S>,
        pipeline: PipelineConfig,
    ) -> Self {
        let timeline = if pipeline.is_pipelined() {
            Some(Arc::new(PipelineTimeline::new(node, pipeline.lookahead)))
        } else {
            None
        };
        Ctx {
            node,
            model,
            kernels: backend.kernels(),
            pipeline,
            timeline,
            preempt: None,
            trace: None,
            flat_collectives: false,
        }
    }

    /// Disable the hierarchical ring-of-rings dispatch on multi-island
    /// fabrics: every collective prices each receiver individually over
    /// its (possibly inter-island) link, serialized on the sender —
    /// the naive baseline `benches/fabric.rs` compares against. No-op
    /// on a flat single-island node, where the two paths are the same
    /// arithmetic.
    pub fn with_flat_collectives(mut self) -> Self {
        self.flat_collectives = true;
        self
    }

    /// Whether collectives should dispatch hierarchically: a
    /// multi-island fabric with the ring-of-rings path enabled.
    fn hier_active(&self) -> bool {
        !self.flat_collectives && self.node.topology().num_islands() > 1
    }

    /// Partition a collective's receivers by island, relative to the
    /// sender: `(locals, remotes)` where `locals` are `from`'s
    /// co-island members (in member order) and each remote island
    /// contributes `(representative, rest)` — the first member seen on
    /// that island crosses the fabric, the rest receive from it.
    /// `None` when the fabric dispatch is off, the node is flat, or no
    /// member lives on a remote island (then the flat arithmetic *is*
    /// the hierarchical one).
    #[allow(clippy::type_complexity)]
    fn hier_split(
        &self,
        from: usize,
        members: &[usize],
    ) -> Option<(Vec<usize>, Vec<(usize, Vec<usize>)>)> {
        if !self.hier_active() {
            return None;
        }
        let topo = self.node.topology();
        let home = topo.island_of(from);
        let mut locals = Vec::new();
        let mut islands: Vec<usize> = Vec::new();
        let mut remotes: Vec<(usize, Vec<usize>)> = Vec::new();
        for &d in members {
            if d == from {
                continue;
            }
            let isl = topo.island_of(d);
            if isl == home {
                locals.push(d);
            } else {
                match islands.iter().position(|&x| x == isl) {
                    Some(i) => remotes[i].1.push(d),
                    None => {
                        islands.push(isl);
                        remotes.push((d, Vec::new()));
                    }
                }
            }
        }
        if remotes.is_empty() {
            None
        } else {
            Some((locals, remotes))
        }
    }

    /// Attach a request trace: subsequent charges emit spans under
    /// `root` in the node tracer. A null trace (or a disabled tracer)
    /// leaves the context untraced — charge helpers stay zero-cost.
    pub fn with_trace(mut self, trace: TraceId, root: SpanId) -> Self {
        let tracer = self.node.tracer();
        if tracer.enabled() && trace != TraceId(0) {
            self.trace = Some(TraceCtx { tracer: tracer.clone(), trace, root });
        }
        self
    }

    /// Emit one span under the request's root, if tracing is attached.
    #[allow(clippy::too_many_arguments)]
    fn trace_span(
        &self,
        name: &str,
        cat: &'static str,
        device: usize,
        stream: &'static str,
        t0_ns: u64,
        t1_ns: u64,
        bytes: u64,
        flops: u64,
    ) {
        if let Some(tc) = &self.trace {
            tc.tracer.span(tc.trace, tc.root, name, cat, device, stream, t0_ns, t1_ns, bytes, flops);
        }
    }

    /// Install a cooperative-preemption hook, invoked at every
    /// [`Ctx::preempt_point`] (the panel boundaries of the distributed
    /// factorizations). The hook must not re-enter this context.
    pub fn with_preempt_hook(mut self, hook: Arc<dyn Fn() + Send + Sync>) -> Self {
        self.preempt = Some(hook);
        self
    }

    /// A panel-boundary yield point: runs the installed preemption hook
    /// (if any). The distributed factor loops call this once per column
    /// tile, so preemption granularity is one panel, never mid-kernel.
    pub fn preempt_point(&self) {
        if let Some(hook) = &self.preempt {
            hook();
        }
    }

    /// The stream timeline, when pipelining is enabled.
    pub fn timeline(&self) -> Option<&PipelineTimeline> {
        self.timeline.as_deref()
    }

    /// Per-device stream snapshot (pipelined contexts only).
    pub fn timeline_snapshot(&self) -> Option<Vec<DeviceTimeline>> {
        self.timeline.as_ref().map(|tl| tl.snapshot())
    }

    /// Bracket a distributed routine: pull streams up to the device
    /// clocks. No-op for barrier contexts.
    pub fn begin_phase(&self) {
        if let Some(tl) = &self.timeline {
            tl.align(self.node);
        }
    }

    /// Close a routine's phase: device clocks jump to the stream
    /// horizons; returns the busy/span report. No-op (`None`) for
    /// barrier contexts.
    pub fn end_phase(&self) -> Option<PhaseReport> {
        self.timeline.as_ref().map(|tl| tl.finish(self.node))
    }

    /// Current compute-stream horizon of `dev` (pipelined), or `0.0`
    /// for barrier contexts where the clocks already carry ordering.
    pub fn device_ready(&self, dev: usize) -> f64 {
        self.timeline.as_ref().map(|tl| tl.compute(dev).horizon()).unwrap_or(0.0)
    }

    /// Charge `dev` with `seconds` of compute-class kernel time.
    /// Barrier: straight onto the device clock. Pipelined: onto the
    /// compute stream (serialized with that device's other updates,
    /// overlapping its panel and copy streams).
    pub fn charge_device_time(&self, dev: usize, seconds: f64, flops: u64) -> crate::Result<()> {
        let traced = self.trace.is_some();
        match &self.timeline {
            Some(tl) => {
                self.node.device(dev)?; // validate the ordinal
                let t0 = if traced { tl.compute(dev).horizon_ns() } else { 0 };
                tl.compute(dev).issue(seconds);
                tl.note_busy(dev, seconds);
                self.node.metrics().add_kernel(flops);
                if traced {
                    self.trace_span(
                        "kernel", "compute", dev, "compute", t0,
                        tl.compute(dev).horizon_ns(), 0, flops,
                    );
                }
                Ok(())
            }
            None => {
                let t0 = if traced { self.node.device(dev)?.clock().now_ns() } else { 0 };
                self.node.charge_kernel(dev, seconds, flops)?;
                if traced {
                    self.trace_span(
                        "kernel", "compute", dev, "compute", t0,
                        self.node.device(dev)?.clock().now_ns(), 0, flops,
                    );
                }
                Ok(())
            }
        }
    }

    /// Charge `dev`'s timeline for a GEMM-class kernel.
    pub fn charge_gemm(&self, dev: usize, m: usize, n: usize, k: usize) -> crate::Result<()> {
        let fl = GpuCostModel::flops_gemm(S::DTYPE, m, n, k);
        self.charge_device_time(dev, self.model.gemm_time(S::DTYPE, m, n, k), fl)
    }

    /// Charge `dev`'s timeline for a panel kernel with `flops` work.
    pub fn charge_panel(&self, dev: usize, flops: u64) -> crate::Result<()> {
        self.charge_device_time(dev, self.model.panel_time(S::DTYPE, flops), flops)
    }

    /// Model a point-to-point transfer of replicated/host-mirrored data
    /// (clock + metrics; the payload is already host-resident in the
    /// simulator, e.g. the pipelined RHS tail in `potrs`). Pipelined
    /// contexts ride the sender's copy stream, gated on its compute
    /// horizon, and the receiver's compute stream waits for completion.
    pub fn charge_p2p(&self, from: usize, to: usize, bytes: usize) -> crate::Result<()> {
        if from == to || bytes == 0 {
            return Ok(());
        }
        let t = self.node.topology().copy_time(from, to, bytes);
        if matches!(self.node.topology().link(from, to), LinkKind::InterNode) {
            self.node.metrics().add_fabric_inter(bytes as u64);
        }
        let traced = self.trace.is_some();
        match &self.timeline {
            Some(tl) => {
                self.node.device(from)?;
                self.node.device(to)?;
                let done = tl.copy(from).issue_after(tl.compute(from).horizon(), t);
                tl.compute(to).wait_event(Event::at(done));
                tl.note_busy(from, t);
                self.node.metrics().add_peer(bytes as u64);
                if traced {
                    let t1 = tl.copy(from).horizon_ns();
                    let dur = (t * 1e9).round() as u64;
                    self.trace_span(
                        "p2p", "xfer", from, "copy", t1.saturating_sub(dur), t1,
                        bytes as u64, 0,
                    );
                }
                Ok(())
            }
            None => {
                let src_clock = self.node.device(from)?.clock();
                let t0 = if traced { src_clock.now_ns() } else { 0 };
                src_clock.advance(t);
                self.node.metrics().add_peer(bytes as u64);
                self.node.device(to)?.clock().sync_to(src_clock.now());
                if traced {
                    self.trace_span("p2p", "xfer", from, "copy", t0, src_clock.now_ns(), bytes as u64, 0);
                }
                Ok(())
            }
        }
    }

    /// The shared pipelined fan-out arithmetic behind
    /// [`Ctx::charge_broadcast`] and [`Ctx::charge_fanout`]: per-
    /// receiver link shares serialize on the sender's copy stream,
    /// gated on its compute horizon. `fence_receivers` is the only
    /// difference between the two callers — a *data* broadcast fences
    /// each receiver's compute stream on delivery, an *output* fan-out
    /// fences nothing.
    fn pipelined_fanout(
        &self,
        tl: &PipelineTimeline,
        from: usize,
        bytes: usize,
        fence_receivers: bool,
    ) -> crate::Result<()> {
        self.node.device(from)?;
        let nd = self.node.num_devices();
        let nb = tl.compute(from).horizon();
        for d in 0..nd {
            if d == from {
                continue;
            }
            let t = self.node.topology().copy_time(from, d, bytes)
                / (nd.max(2) - 1) as f64; // link shared across fan-out
            let done = tl.copy(from).issue_after(nb, t);
            tl.note_busy(from, t);
            self.node.metrics().add_peer(bytes as u64);
            if fence_receivers {
                tl.compute(d).wait_event(Event::at(done));
            }
        }
        Ok(())
    }

    /// Model a replicated-data synchronization: `bytes` flowing from
    /// `from` to every other device (clock + metrics; the payload is
    /// already host-resident in the simulator). Pipelined contexts use
    /// the sender's copy stream with the same shared-link arithmetic.
    pub fn charge_broadcast(&self, from: usize, bytes: usize) -> crate::Result<()> {
        let nd = self.node.num_devices();
        if self.hier_active() {
            // Ring-of-rings on the fabric: one representative per
            // remote island crosses the inter-node link, then fans out
            // locally — instead of every receiver paying the fabric.
            let members: Vec<usize> = (0..nd).collect();
            return self.group_broadcast_contended("bcast", from, &members, bytes, 1);
        }
        let traced = self.trace.is_some();
        match &self.timeline {
            Some(tl) => {
                let t0 = if traced { tl.copy(from).horizon_ns() } else { 0 };
                self.pipelined_fanout(tl, from, bytes, true)?;
                if traced {
                    self.trace_span(
                        "bcast", "collective", from, "copy", t0, tl.copy(from).horizon_ns(),
                        (bytes * (nd.saturating_sub(1))) as u64, 0,
                    );
                }
                Ok(())
            }
            None => {
                let src_clock = self.node.device(from)?.clock();
                let t0 = if traced { src_clock.now_ns() } else { 0 };
                for d in 0..nd {
                    if d == from {
                        continue;
                    }
                    let t = self.node.topology().copy_time(from, d, bytes);
                    src_clock.advance(t / (nd.max(2) - 1) as f64); // link shared across fan-out
                    self.node.metrics().add_peer(bytes as u64);
                    self.node.device(d)?.clock().sync_to(src_clock.now());
                }
                if traced {
                    self.trace_span(
                        "bcast", "collective", from, "copy", t0, src_clock.now_ns(),
                        (bytes * (nd.saturating_sub(1))) as u64, 0,
                    );
                }
                Ok(())
            }
        }
    }

    /// Model a replicated-***output*** fan-out: `bytes` of
    /// already-computed results flowing from `from` to every other
    /// device. Barrier contexts charge exactly like
    /// [`Ctx::charge_broadcast`] (the seed clock behaviour). Pipelined
    /// contexts put the shares on the sender's copy stream, gated on
    /// its compute horizon, but fence **nothing** on the receivers:
    /// a `cudaMemcpyPeerAsync` push lands in the receiver's memory
    /// without occupying its streams, and no downstream kernel
    /// consumes a replicated result — so an output fan-out must not
    /// stall the pipeline. Delivery completion is carried by the
    /// sender's copy-stream horizon (and thus still bounds the
    /// makespan when it is the true tail). This is what lets the
    /// `potrs` backward sweep's per-tile result broadcasts overlap
    /// with the substitution chain.
    pub fn charge_fanout(&self, from: usize, bytes: usize) -> crate::Result<()> {
        match &self.timeline {
            Some(tl) => {
                if self.hier_active() {
                    // Hierarchical output fan-out: the ring-of-rings
                    // schedule, with receiver fences omitted exactly
                    // like the flat pipelined path.
                    self.node.device(from)?;
                    let nd = self.node.num_devices();
                    if nd <= 1 || bytes == 0 {
                        return Ok(());
                    }
                    let members: Vec<usize> = (0..nd).collect();
                    let nb = tl.compute(from).horizon();
                    self.pipelined_group_broadcast(
                        tl, "fanout", from, &members, bytes, nb, false, 1,
                    )?;
                    return Ok(());
                }
                let traced = self.trace.is_some();
                let t0 = if traced { tl.copy(from).horizon_ns() } else { 0 };
                self.pipelined_fanout(tl, from, bytes, false)?;
                if traced {
                    let nd = self.node.num_devices();
                    self.trace_span(
                        "fanout", "collective", from, "copy", t0, tl.copy(from).horizon_ns(),
                        (bytes * (nd.saturating_sub(1))) as u64, 0,
                    );
                }
                Ok(())
            }
            None => self.charge_broadcast(from, bytes),
        }
    }

    /// Model a replicated-data synchronization scoped to a device
    /// *group* (a 2D grid row or column): `bytes` flowing from `from`
    /// to every other member of `members`. Same shared-link arithmetic
    /// as [`Ctx::charge_broadcast`], but disjoint groups ride disjoint
    /// source links, so grid-parallel collectives overlap — the 2D
    /// `syevd` path's win. Members not containing `from`, duplicates,
    /// or a singleton group charge nothing extra beyond the listed
    /// receivers.
    pub fn charge_group_broadcast(&self, from: usize, members: &[usize], bytes: usize) -> crate::Result<()> {
        self.group_broadcast_impl("group_bcast", from, members, bytes)
    }

    /// [`Ctx::charge_group_broadcast`]'s body, with the span name the
    /// caller wants ("group_bcast" or the ring collectives' axis name).
    fn group_broadcast_impl(
        &self,
        span_name: &'static str,
        from: usize,
        members: &[usize],
        bytes: usize,
    ) -> crate::Result<()> {
        self.group_broadcast_contended(span_name, from, members, bytes, 1)
    }

    /// Group broadcast with `concurrent` transfers sharing each
    /// receiver's link (the grid column rings' per-link contention
    /// term — `concurrent == 1` is bitwise the uncontended path). On a
    /// multi-island fabric this dispatches to the hierarchical
    /// ring-of-rings schedule; on a flat node (or when every member is
    /// co-island with `from`) it is the exact single-node arithmetic.
    fn group_broadcast_contended(
        &self,
        span_name: &'static str,
        from: usize,
        members: &[usize],
        bytes: usize,
        concurrent: usize,
    ) -> crate::Result<()> {
        let receivers = members.iter().filter(|&&d| d != from).count();
        if receivers == 0 || bytes == 0 {
            return Ok(());
        }
        match &self.timeline {
            Some(tl) => {
                self.node.device(from)?;
                let nb = tl.compute(from).horizon();
                self.pipelined_group_broadcast(
                    tl, span_name, from, members, bytes, nb, true, concurrent,
                )?;
                Ok(())
            }
            None => self.barrier_group_broadcast(span_name, from, members, bytes, concurrent),
        }
    }

    /// The pipelined group-broadcast schedule: per-receiver shares on
    /// the sender's copy stream gated on `not_before`, hierarchical on
    /// a fabric (crossings first so remote islands fan out in parallel
    /// with the local shares, each remote island relaying on its
    /// representative's copy stream). Returns each member's delivery
    /// time so ring callers (the grid potrf) can gate per-tile work;
    /// `fence` additionally fences each receiver's compute stream on
    /// delivery (the `charge_*` data-broadcast semantics).
    #[allow(clippy::too_many_arguments)]
    fn pipelined_group_broadcast(
        &self,
        tl: &PipelineTimeline,
        span_name: &'static str,
        from: usize,
        members: &[usize],
        bytes: usize,
        not_before: f64,
        fence: bool,
        concurrent: usize,
    ) -> crate::Result<Vec<(usize, f64)>> {
        self.node.device(from)?;
        let topo = self.node.topology();
        let traced = self.trace.is_some();
        let t0 = if traced { tl.copy(from).horizon_ns() } else { 0 };
        let receivers = members.iter().filter(|&&d| d != from).count();
        let mut arrivals = Vec::with_capacity(receivers);
        match self.hier_split(from, members) {
            Some((locals, remotes)) => {
                let m = self.node.metrics();
                // Stage B: fabric crossings, serialized on the
                // sender's copy stream (the inter-node pipe is shared).
                let mut rep_done = Vec::with_capacity(remotes.len());
                for (rep, _) in &remotes {
                    let tb = topo.contended_time(from, *rep, bytes, concurrent);
                    let done = tl.copy(from).issue_after(not_before, tb);
                    tl.note_busy(from, tb);
                    m.add_peer(bytes as u64);
                    m.add_fabric_inter(bytes as u64);
                    if fence {
                        tl.compute(*rep).wait_event(Event::at(done));
                    }
                    arrivals.push((*rep, done));
                    rep_done.push(done);
                    if traced {
                        let t1 = tl.copy(from).horizon_ns();
                        let dur = (tb * 1e9).round() as u64;
                        self.trace_span(
                            "fabric-hop", "collective", from, "fabric",
                            t1.saturating_sub(dur), t1, bytes as u64, 0,
                        );
                    }
                }
                // Stage A: the sender's own island, flat shares.
                for &d in &locals {
                    let ta = topo.ring_share_time(from, d, bytes, locals.len(), concurrent);
                    let done = tl.copy(from).issue_after(not_before, ta);
                    tl.note_busy(from, ta);
                    m.add_peer(bytes as u64);
                    m.add_fabric_intra(bytes as u64);
                    if fence {
                        tl.compute(d).wait_event(Event::at(done));
                    }
                    arrivals.push((d, done));
                }
                // Stage C: each representative relays island-locally on
                // its own copy stream — islands fan out in parallel.
                for ((rep, rest), rdone) in remotes.iter().zip(rep_done) {
                    for &d in rest {
                        let tc = topo.ring_share_time(*rep, d, bytes, rest.len(), concurrent);
                        let done = tl.copy(*rep).issue_after(rdone, tc);
                        tl.note_busy(*rep, tc);
                        m.add_peer(bytes as u64);
                        m.add_fabric_intra(bytes as u64);
                        if fence {
                            tl.compute(d).wait_event(Event::at(done));
                        }
                        arrivals.push((d, done));
                    }
                }
                m.add_fabric_bcast(
                    1 + u64::from(!locals.is_empty())
                        + remotes.iter().filter(|(_, rest)| !rest.is_empty()).count() as u64,
                );
            }
            None => {
                for &d in members {
                    if d == from {
                        continue;
                    }
                    let t = topo.ring_share_time(from, d, bytes, receivers, concurrent);
                    let done = tl.copy(from).issue_after(not_before, t);
                    tl.note_busy(from, t);
                    self.node.metrics().add_peer(bytes as u64);
                    if fence {
                        tl.compute(d).wait_event(Event::at(done));
                    }
                    arrivals.push((d, done));
                }
            }
        }
        if traced {
            self.trace_span(
                span_name, "collective", from, "copy", t0, tl.copy(from).horizon_ns(),
                (bytes * receivers) as u64, 0,
            );
        }
        Ok(arrivals)
    }

    /// The barrier group-broadcast schedule: the same hierarchical
    /// dispatch on clocks instead of streams (crossings advance the
    /// sender, representatives relay on their own clocks).
    fn barrier_group_broadcast(
        &self,
        span_name: &'static str,
        from: usize,
        members: &[usize],
        bytes: usize,
        concurrent: usize,
    ) -> crate::Result<()> {
        let topo = self.node.topology();
        let traced = self.trace.is_some();
        let src_clock = self.node.device(from)?.clock();
        let t0 = if traced { src_clock.now_ns() } else { 0 };
        match self.hier_split(from, members) {
            Some((locals, remotes)) => {
                let m = self.node.metrics();
                for (rep, _) in &remotes {
                    let tb = topo.contended_time(from, *rep, bytes, concurrent);
                    let f0 = if traced { src_clock.now_ns() } else { 0 };
                    src_clock.advance(tb);
                    m.add_peer(bytes as u64);
                    m.add_fabric_inter(bytes as u64);
                    self.node.device(*rep)?.clock().sync_to(src_clock.now());
                    if traced {
                        self.trace_span(
                            "fabric-hop", "collective", from, "fabric",
                            f0, src_clock.now_ns(), bytes as u64, 0,
                        );
                    }
                }
                for &d in &locals {
                    let ta = topo.ring_share_time(from, d, bytes, locals.len(), concurrent);
                    src_clock.advance(ta);
                    m.add_peer(bytes as u64);
                    m.add_fabric_intra(bytes as u64);
                    self.node.device(d)?.clock().sync_to(src_clock.now());
                }
                for (rep, rest) in &remotes {
                    let rep_clock = self.node.device(*rep)?.clock();
                    for &d in rest {
                        let tc = topo.ring_share_time(*rep, d, bytes, rest.len(), concurrent);
                        rep_clock.advance(tc);
                        m.add_peer(bytes as u64);
                        m.add_fabric_intra(bytes as u64);
                        self.node.device(d)?.clock().sync_to(rep_clock.now());
                    }
                }
                m.add_fabric_bcast(
                    1 + u64::from(!locals.is_empty())
                        + remotes.iter().filter(|(_, rest)| !rest.is_empty()).count() as u64,
                );
            }
            None => {
                let receivers = members.iter().filter(|&&d| d != from).count();
                for &d in members {
                    if d == from {
                        continue;
                    }
                    let t = topo.ring_share_time(from, d, bytes, receivers, concurrent);
                    src_clock.advance(t);
                    self.node.metrics().add_peer(bytes as u64);
                    self.node.device(d)?.clock().sync_to(src_clock.now());
                }
            }
        }
        if traced {
            let receivers = members.iter().filter(|&&d| d != from).count();
            self.trace_span(
                span_name, "collective", from, "copy", t0, src_clock.now_ns(),
                (bytes * receivers) as u64, 0,
            );
        }
        Ok(())
    }

    /// Tally `bytes` onto the per-axis grid collective counter.
    fn note_ring_bytes(&self, axis: RingAxis, bytes: u64) {
        match axis {
            RingAxis::Row => self.node.metrics().add_grid_row_bytes(bytes),
            RingAxis::Col => self.node.metrics().add_grid_col_bytes(bytes),
        }
    }

    /// A **ring collective** along one grid axis: the generalization of
    /// [`Ctx::charge_group_broadcast`] the grid-native solvers schedule
    /// with. Timing is identical to a group broadcast of `bytes` from
    /// `from` to `members` (per-receiver shares serialize on the
    /// sender's copy stream when pipelined, on its clock when
    /// barrier-scheduled; receivers' compute streams fence on
    /// delivery), but the carried bytes are additionally tallied per
    /// axis (`grid_row_bytes` / `grid_col_bytes`) — the counters that
    /// expose the 2D layouts' broadcast-volume win over the 1D
    /// devices-wide pattern.
    pub fn charge_ring_broadcast(
        &self,
        axis: RingAxis,
        from: usize,
        members: &[usize],
        bytes: usize,
    ) -> crate::Result<()> {
        let receivers = members.iter().filter(|&&d| d != from).count();
        if receivers > 0 && bytes > 0 {
            self.note_ring_bytes(axis, (bytes * receivers) as u64);
        }
        let name = match axis {
            RingAxis::Row => "ring-row",
            RingAxis::Col => "ring-col",
        };
        self.group_broadcast_impl(name, from, members, bytes)
    }

    /// [`Ctx::charge_ring_broadcast`] with an explicit per-link
    /// contention factor: `concurrent` simultaneous transfers share
    /// each receiver's link (the grid column rings at a pivot step,
    /// where every source row broadcasts down its column at once).
    /// `concurrent == 1` is bitwise [`Ctx::charge_ring_broadcast`].
    pub fn charge_ring_broadcast_contended(
        &self,
        axis: RingAxis,
        from: usize,
        members: &[usize],
        bytes: usize,
        concurrent: usize,
    ) -> crate::Result<()> {
        let receivers = members.iter().filter(|&&d| d != from).count();
        if receivers > 0 && bytes > 0 {
            self.note_ring_bytes(axis, (bytes * receivers) as u64);
        }
        let name = match axis {
            RingAxis::Row => "ring-row",
            RingAxis::Col => "ring-col",
        };
        self.group_broadcast_contended(name, from, members, bytes, concurrent)
    }

    /// The pipelined ring broadcast the grid potrf hand-schedules: the
    /// same schedule as [`Ctx::charge_ring_broadcast_contended`]'s
    /// pipelined arm, but gated on an explicit `not_before` horizon
    /// (the producing kernel's completion, not the sender's compute
    /// horizon) and **without** the receiver compute fence — the caller
    /// gates per-tile work on the returned `(device, delivery)` pairs
    /// instead. Errors under the barrier scheduler (no timeline).
    pub fn pipelined_ring_arrivals(
        &self,
        axis: RingAxis,
        from: usize,
        members: &[usize],
        bytes: usize,
        not_before: f64,
        concurrent: usize,
    ) -> crate::Result<Vec<(usize, f64)>> {
        let tl = self.timeline.as_ref().ok_or_else(|| {
            crate::Error::config("pipelined_ring_arrivals requires the pipelined scheduler")
        })?;
        let receivers = members.iter().filter(|&&d| d != from).count();
        if receivers == 0 || bytes == 0 {
            return Ok(Vec::new());
        }
        self.note_ring_bytes(axis, (bytes * receivers) as u64);
        let name = match axis {
            RingAxis::Row => "ring-row",
            RingAxis::Col => "ring-col",
        };
        self.pipelined_group_broadcast(tl, name, from, members, bytes, not_before, false, concurrent)
    }

    /// Row-ring broadcast: `bytes` from `from` to its grid-row peers.
    pub fn charge_row_ring_broadcast(
        &self,
        from: usize,
        members: &[usize],
        bytes: usize,
    ) -> crate::Result<()> {
        self.charge_ring_broadcast(RingAxis::Row, from, members, bytes)
    }

    /// Column-ring broadcast: `bytes` from `from` to its grid-column
    /// peers.
    pub fn charge_col_ring_broadcast(
        &self,
        from: usize,
        members: &[usize],
        bytes: usize,
    ) -> crate::Result<()> {
        self.charge_ring_broadcast(RingAxis::Col, from, members, bytes)
    }

    /// A point-to-point hop along one grid axis (a tail hand-off within
    /// a grid row, a partial-result reduction up a grid column):
    /// timing-identical to [`Ctx::charge_p2p`], plus the per-axis byte
    /// tally.
    pub fn charge_ring_p2p(
        &self,
        axis: RingAxis,
        from: usize,
        to: usize,
        bytes: usize,
    ) -> crate::Result<()> {
        if from != to && bytes > 0 {
            self.note_ring_bytes(axis, bytes as u64);
        }
        self.charge_p2p(from, to, bytes)
    }

    /// Move a packed panel buffer between two device scratch
    /// allocations (base pointers) and charge the transfer.
    ///
    /// Barrier: the exact seed behaviour (`SimNode::peer_copy`, clocks
    /// carry the dependency; returns `0.0`). Pipelined: bytes move via
    /// the untimed DMA path, the transfer rides the *sender's copy
    /// stream* gated on `not_before`, the receiver's compute stream is
    /// fenced on completion, and the completion time is returned so
    /// callers (potrf's trailing updates) can gate finer-grained work.
    pub fn panel_copy(
        &self,
        src: DevPtr,
        dst: DevPtr,
        bytes: usize,
        not_before: f64,
    ) -> crate::Result<f64> {
        let traced = self.trace.is_some();
        match &self.timeline {
            Some(tl) => {
                self.node.peer_copy_untimed(src, 0, dst, 0, bytes)?;
                let t = self.node.topology().copy_time(src.device, dst.device, bytes);
                let done = tl.copy(src.device).issue_after(not_before, t);
                tl.note_busy(src.device, t);
                tl.compute(dst.device).wait_event(Event::at(done));
                if traced {
                    let t1 = tl.copy(src.device).horizon_ns();
                    let dur = (t * 1e9).round() as u64;
                    self.trace_span(
                        "panel_copy", "xfer", src.device, "copy",
                        t1.saturating_sub(dur), t1, bytes as u64, 0,
                    );
                }
                Ok(done)
            }
            None => {
                let t0 = if traced {
                    self.node.device(src.device)?.clock().now_ns()
                } else {
                    0
                };
                self.node.peer_copy(src, 0, dst, 0, bytes)?;
                if traced {
                    self.trace_span(
                        "panel_copy", "xfer", src.device, "copy", t0,
                        self.node.device(src.device)?.clock().now_ns(), bytes as u64, 0,
                    );
                }
                Ok(0.0)
            }
        }
    }
}

/// Lift a pipelined routine's per-device×stream horizons
/// ([`PipelineTimeline::snapshot`]) into summary spans under `parent`.
///
/// The lookahead schedules issue panel/copy work directly onto their
/// streams (bypassing the per-charge helpers), so this is how a traced
/// request captures those stages: one `stage:<stream>` span per
/// device×stream covering `[0, horizon]` on the exact integer-ns
/// timeline the streams already carry. No-op for empty horizons or a
/// null/disabled trace.
pub fn lift_timeline_spans(
    tracer: &Tracer,
    trace: TraceId,
    parent: SpanId,
    snap: &[DeviceTimeline],
) {
    if !tracer.enabled() || trace == TraceId(0) {
        return;
    }
    for tl in snap {
        for (stream, horizon) in [
            ("compute", tl.compute_horizon),
            ("panel", tl.panel_horizon),
            ("copy", tl.copy_horizon),
        ] {
            let t1 = (horizon * 1e9).round() as u64;
            if t1 == 0 {
                continue;
            }
            tracer.span(
                trace,
                parent,
                &format!("stage:{stream}"),
                "stage",
                tl.device,
                stream,
                0,
                t1,
                0,
                0,
            );
        }
    }
}
