//! Distributed dense solvers over the 1D block-cyclic layout — the
//! cuSOLVERMg substrate itself (`potrf`/`potrs`/`potri`/`syevd`).
//!
//! Each routine is a *coordinator-scheduled* blocked algorithm: tile
//! kernels run "on" the simulated device owning the tile (charging that
//! device's timeline via the cost model), panels move between devices
//! with peer copies, and the numerical payload of every tile kernel is
//! delegated to a [`TileKernels`] backend:
//!
//! * [`NativeKernels`] — pure-Rust reference compute (`crate::linalg`);
//! * [`crate::runtime::XlaKernels`] — the AOT-compiled XLA executables
//!   produced by the Python layers (Pallas GEMM + JAX panel ops), the
//!   production path: Python authored them, but only Rust runs them.
//!
//! The two backends are interchangeable and cross-checked in the test
//! suite, which is the correctness argument for the AOT path.

mod kernels;
mod potrf;
mod potri;
mod potrs;
mod syevd;

pub use kernels::{NativeKernels, TileKernels};
pub use potrf::potrf_dist;
pub use potri::potri_dist;
pub use potrs::potrs_dist;
pub use syevd::syevd_dist;

use crate::costmodel::GpuCostModel;
use crate::device::SimNode;
use crate::scalar::Scalar;
use std::sync::Arc;

/// Which compute backend the solvers use for tile kernels.
#[derive(Clone)]
pub enum SolverBackend<S: Scalar> {
    /// Pure-Rust tile kernels (reference; always available).
    Native,
    /// AOT-compiled XLA executables loaded via PJRT.
    Xla(Arc<dyn TileKernels<S>>),
}

impl<S: Scalar> SolverBackend<S> {
    /// Resolve to a concrete kernel set.
    pub fn kernels(&self) -> Arc<dyn TileKernels<S>> {
        match self {
            SolverBackend::Native => Arc::new(NativeKernels),
            SolverBackend::Xla(k) => k.clone(),
        }
    }
}

impl<S: Scalar> std::fmt::Debug for SolverBackend<S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SolverBackend::Native => f.write_str("SolverBackend::Native"),
            SolverBackend::Xla(_) => f.write_str("SolverBackend::Xla"),
        }
    }
}

/// Shared state threaded through the solver routines. Public so
/// integration tests, benches and examples can drive the distributed
/// solvers directly (the `JaxMg` front end wraps this for normal use).
pub struct Ctx<'a, S: Scalar> {
    pub node: &'a SimNode,
    pub model: &'a GpuCostModel,
    pub kernels: Arc<dyn TileKernels<S>>,
}

impl<'a, S: Scalar> Ctx<'a, S> {
    pub fn new(node: &'a SimNode, model: &'a GpuCostModel, backend: &SolverBackend<S>) -> Self {
        Ctx { node, model, kernels: backend.kernels() }
    }

    /// Charge `dev`'s timeline for a GEMM-class kernel.
    pub fn charge_gemm(&self, dev: usize, m: usize, n: usize, k: usize) -> crate::Result<()> {
        let fl = GpuCostModel::flops_gemm(S::DTYPE, m, n, k);
        self.node.charge_kernel(dev, self.model.gemm_time(S::DTYPE, m, n, k), fl)
    }

    /// Charge `dev`'s timeline for a panel kernel with `flops` work.
    pub fn charge_panel(&self, dev: usize, flops: u64) -> crate::Result<()> {
        self.node.charge_kernel(dev, self.model.panel_time(S::DTYPE, flops), flops)
    }

    /// Model a point-to-point transfer of replicated/host-mirrored data
    /// (clock + metrics; the payload is already host-resident in the
    /// simulator, e.g. the pipelined RHS tail in `potrs`).
    pub fn charge_p2p(&self, from: usize, to: usize, bytes: usize) -> crate::Result<()> {
        if from == to || bytes == 0 {
            return Ok(());
        }
        let t = self.node.topology().copy_time(from, to, bytes);
        let src_clock = self.node.device(from)?.clock();
        src_clock.advance(t);
        self.node.metrics().add_peer(bytes as u64);
        self.node.device(to)?.clock().sync_to(src_clock.now());
        Ok(())
    }

    /// Model a replicated-data synchronization: `bytes` flowing from
    /// `from` to every other device (clock + metrics; the payload is
    /// already host-resident in the simulator).
    pub fn charge_broadcast(&self, from: usize, bytes: usize) -> crate::Result<()> {
        let nd = self.node.num_devices();
        let src_clock = self.node.device(from)?.clock();
        for d in 0..nd {
            if d == from {
                continue;
            }
            let t = self.node.topology().copy_time(from, d, bytes);
            src_clock.advance(t / (nd.max(2) - 1) as f64); // link shared across fan-out
            self.node.metrics().add_peer(bytes as u64);
            self.node.device(d)?.clock().sync_to(src_clock.now());
        }
        Ok(())
    }
}
