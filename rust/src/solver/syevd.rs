//! Distributed symmetric/Hermitian eigensolver (the `cusolverMgSyevd`
//! analogue): eigenvalues + eigenvectors of a block-cyclic `DistMatrix`.
//!
//! Three stages, mirroring the classical multi-GPU `syevd` pipeline:
//!
//! 1. **Distributed Householder tridiagonalization.** For each column
//!    `k`: the owner forms the Householder reflector from its column,
//!    broadcasts it; every device contracts its local columns against
//!    the reflector (`A·u`, a BLAS-2 matvec over the cyclic layout),
//!    partial results are all-reduced, and each device applies the
//!    rank-2 update to its own columns. FLOP-parallel but HBM-bound —
//!    which is exactly why the paper's Fig. 3c shows syevd nearly
//!    independent of `T_A`.
//! 2. **Tridiagonal eigensolve** (implicit-shift QL, `tql2`) on the
//!    lead device — small `O(n)` data, `O(n²)`–`O(n³)` flops, serial.
//! 3. **Distributed back-transformation.** The tridiagonal eigenvectors
//!    are scattered column-cyclically; each device applies the stored
//!    reflectors (and the realifying phase diagonal) to its local
//!    columns — embarrassingly parallel rank-1 updates.
//!
//! ## 1D vs 2D layouts
//!
//! On the **1D column layout** every device owns whole rows of its
//! columns, so each step's reflector collectives carry full length-`n`
//! vectors through one owner — the row-bound behaviour the paper calls
//! out (§5). On a **`P × Q` grid** ([`crate::layout::BlockCyclic2D`])
//! the reflector is born distributed over `P` row blocks: its
//! broadcasts, the partial-`A·u` reductions and the `w` fan-out run as
//! `P` parallel row-group collectives of `≈ n/P` words on disjoint
//! source links, and the rank-2/back-transform updates are charged per
//! `local_rows × local_cols` block. `P = 1` grids take the 1D code path
//! (their storage is bitwise columnar), so a `1 × Q` grid is
//! bitwise-identical to the native 1D layout — results *and* schedule.
//!
//! During the solve the matrix is mirrored host-side (one read per
//! panel, not per step); all compute is still *charged* to the owning
//! device's timeline, and reflector broadcasts / all-reduces are
//! charged to the NVLink model. See DESIGN.md §Hardware substitution.

use super::{Ctx, RingAxis};
use crate::error::{Error, Result};
use crate::layout::{BlockCyclic2D, MatrixLayout};
use crate::linalg::{tql2, Matrix, Tridiagonal};
use crate::scalar::{RealScalar, Scalar};
use crate::tile::DistMatrix;

/// Eigendecomposition in place: on return `a`'s panels hold the
/// eigenvector columns (same layout) and the ascending eigenvalues are
/// returned. Accepts the 1D block-cyclic layout (and `P = 1` grids,
/// which share its storage bitwise) or a 2D [`BlockCyclic2D`] grid.
pub fn syevd_dist<S: Scalar>(ctx: &Ctx<'_, S>, a: &mut DistMatrix<S>) -> Result<Vec<S::Real>> {
    if let Some(lay) = a.layout().compat_1d(a.rows()) {
        return syevd_dist_1d(ctx, a, lay);
    }
    if let Some(grid) = a.layout().grid2d().copied() {
        return syevd_dist_grid(ctx, a, grid);
    }
    Err(Error::layout(
        "syevd requires a block-cyclic layout (1D columns or 2D grid) — redistribute first",
    ))
}

/// The original 1D path: whole-column ownership per device.
fn syevd_dist_1d<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    lay: crate::layout::BlockCyclic1D,
) -> Result<Vec<S::Real>> {
    use crate::layout::ColumnLayout;
    let n = a.rows();
    if n != a.cols() {
        return Err(Error::shape(format!("syevd needs square matrix, got {}x{}", n, a.cols())));
    }
    if n == 0 {
        return Ok(vec![]);
    }
    let ndev = ctx.node.num_devices();
    let esize = std::mem::size_of::<S>();

    // Pipelined contexts route every charge below onto the per-device
    // compute/copy streams (`Ctx::charge_device_time` and friends), so
    // reflector broadcasts overlap the rank-2 updates; barrier contexts
    // keep the seed clock behaviour.
    ctx.begin_phase();

    // Host mirror of each device panel (read once; see module docs).
    let mut panels: Vec<Matrix<S>> = Vec::with_capacity(ndev);
    for d in 0..ndev {
        let lc = lay.local_cols(d);
        panels.push(a.read_block(d, 0, n, 0, lc)?);
    }
    let col = |panels: &[Matrix<S>], g: usize| -> Vec<S> {
        let (d, loc) = lay.place(g);
        panels[d].col(loc).to_vec()
    };

    // ---- Stage 1: Householder tridiagonalization.
    let mut reflectors: Vec<(Vec<S>, S)> = Vec::new(); // (u, tau), u zero above k+1
    for k in 0..n.saturating_sub(2) {
        let owner = lay.owner_of(k);
        let ak = col(&panels, k);

        // Form the reflector on the column's owner.
        let mut xnorm_sq = <S::Real as RealScalar>::rzero();
        for i in (k + 1)..n {
            xnorm_sq = xnorm_sq + ak[i].abs_sqr();
        }
        ctx.charge_device_time(owner, ctx.model.blas2_time((2 * (n - k) * esize) as u64), 0)?;
        let xnorm = xnorm_sq.rsqrt_val();
        if xnorm.to_f64() == 0.0 {
            reflectors.push((vec![S::zero(); n], S::zero()));
            continue;
        }
        let alpha = ak[k + 1];
        let aabs = alpha.abs();
        let phase = if aabs.to_f64() == 0.0 {
            S::one()
        } else {
            alpha * S::from_real(<S::Real as RealScalar>::rone() / aabs)
        };
        let beta = -phase * S::from_real(xnorm);
        let mut u = vec![S::zero(); n];
        let mut unorm_sq = <S::Real as RealScalar>::rzero();
        for i in (k + 1)..n {
            let ui = if i == k + 1 { ak[i] - beta } else { ak[i] };
            u[i] = ui;
            unorm_sq = unorm_sq + ui.abs_sqr();
        }
        if unorm_sq.to_f64() == 0.0 {
            reflectors.push((u, S::zero()));
            continue;
        }
        let tau = S::from_real(<S::Real as RealScalar>::from_f64(2.0) / unorm_sq);

        // Broadcast the reflector to every device.
        ctx.charge_broadcast(owner, (n - k) * esize)?;

        // w = τ·A·u − ½τ²(uᴴAu)·u ; A·u computed as a distributed
        // matvec: each device contracts its local columns, partials are
        // all-reduced on the owner.
        let mut au = vec![S::zero(); n];
        for d in 0..ndev {
            let lc = lay.local_cols(d);
            let pd = &panels[d];
            let mut partial = vec![S::zero(); n];
            for loc in 0..lc {
                let g = lay.global_index(d, loc);
                let ug = u[g];
                if ug == S::zero() {
                    continue;
                }
                let cd = pd.col(loc);
                for i in 0..n {
                    partial[i] += cd[i] * ug;
                }
            }
            // gemv flops: 2·n·lc, bandwidth-bound.
            ctx.charge_device_time(d, ctx.model.blas2_time((n * lc * esize) as u64), (2 * n * lc) as u64)?;
            ctx.charge_p2p(d, owner, n * esize)?; // reduce to owner
            for i in 0..n {
                au[i] += partial[i];
            }
        }
        ctx.charge_broadcast(owner, n * esize)?; // w back out

        let mut uhau = S::zero();
        for i in (k + 1)..n {
            uhau += u[i].conj() * au[i];
        }
        let half = S::from_f64(0.5);
        let mut w = vec![S::zero(); n];
        for i in 0..n {
            w[i] = tau * au[i] - half * tau * tau * uhau * u[i];
        }

        // Rank-2 update of each device's local columns:
        // A[:,g] −= u·conj(w_g) + w·conj(u_g).
        for d in 0..ndev {
            let lc = lay.local_cols(d);
            let pd = &mut panels[d];
            for loc in 0..lc {
                let g = lay.global_index(d, loc);
                let wg = w[g].conj();
                let ug = u[g].conj();
                let cd = pd.col_mut(loc);
                if wg != S::zero() || ug != S::zero() {
                    for i in 0..n {
                        cd[i] -= u[i] * wg + w[i] * ug;
                    }
                }
            }
            ctx.charge_device_time(d, ctx.model.blas2_time((2 * n * lc * esize) as u64), (4 * n * lc) as u64)?;
        }

        reflectors.push((u, tau));
    }

    // Extract the (possibly complex-subdiagonal) tridiagonal, realify
    // via a phase diagonal folded into the back-transform.
    let mut d_diag = vec![<S::Real as RealScalar>::rzero(); n];
    let mut e_sub = vec![<S::Real as RealScalar>::rzero(); n.saturating_sub(1)];
    let mut phases = vec![S::one(); n];
    {
        let mut p = S::one();
        for i in 0..n {
            d_diag[i] = col(&panels, i)[i].re();
        }
        for k in 0..n.saturating_sub(1) {
            let ek = col(&panels, k)[k + 1];
            let eabs = ek.abs();
            e_sub[k] = eabs;
            let phase = if eabs.to_f64() == 0.0 {
                S::one()
            } else {
                ek * S::from_real(<S::Real as RealScalar>::rone() / eabs)
            };
            p = p * phase;
            phases[k + 1] = p;
        }
    }

    // ---- Stage 2: tridiagonal QL on the lead device.
    let tri = Tridiagonal { d: d_diag, e: e_sub };
    let mut z = Matrix::<S>::eye(n);
    let values = tql2(&tri, &mut z)?;
    // QL with eigenvectors is ~6n³ HBM-bound flops on one device; this
    // T_A-independent term dominates syevd (paper Fig. 3c).
    ctx.charge_device_time(0, ctx.model.blas2_time((6 * n * n * esize) as u64), (6 * n * n * n) as u64)?;
    // Scatter the tridiagonal eigenvectors column-cyclically.
    ctx.charge_broadcast(0, n * n.div_ceil(ndev) * esize)?;

    // ---- Stage 3: distributed back-transform V = (H₀···H_{n-3})·D·Z.
    for d in 0..ndev {
        let lc = lay.local_cols(d);
        let pd = &mut panels[d];
        for loc in 0..lc {
            let g = lay.global_index(d, loc);
            let dst = pd.col_mut(loc);
            // D·Z: row i scaled by phases[i].
            for i in 0..n {
                dst[i] = phases[i] * z[(i, g)];
            }
            // Apply reflectors in reverse: v ← v − u·(τ·(uᴴ v)).
            for (u, tau) in reflectors.iter().rev() {
                if *tau == S::zero() {
                    continue;
                }
                let mut uhv = S::zero();
                for i in 0..n {
                    uhv += u[i].conj() * dst[i];
                }
                let t = *tau * uhv;
                for i in 0..n {
                    dst[i] -= u[i] * t;
                }
            }
        }
        ctx.charge_device_time(
            d,
            ctx.model.blas2_time((4 * n * lc * esize) as u64) * reflectors.len().max(1) as f64,
            (4 * n * lc * reflectors.len()) as u64,
        )?;
    }

    // Write the eigenvector panels back to the devices.
    for d in 0..ndev {
        a.write_block(d, 0, 0, &panels[d])?;
    }
    let _ = ctx.end_phase();
    Ok(values)
}

/// The 2D grid path (`P > 1`): identical numerics computed from a host
/// mirror, with compute charged per `local_rows × local_cols` block and
/// the reflector collectives charged as `P` parallel row-group
/// transfers of row segments — the un-row-binding the paper's §5 asks
/// for. The back-transform's column-group reductions are charged per
/// `tile_c`-wide reflector block (blocked WY application), so their
/// latency amortizes.
fn syevd_dist_grid<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    grid: BlockCyclic2D,
) -> Result<Vec<S::Real>> {
    let n = a.rows();
    if n != a.cols() {
        return Err(Error::shape(format!("syevd needs square matrix, got {}x{}", n, a.cols())));
    }
    if n == 0 {
        return Ok(vec![]);
    }
    let (p, q) = grid.grid();
    let ndev = ctx.node.num_devices();
    let esize = std::mem::size_of::<S>();
    ctx.node.metrics().note_grid_solve(p as u64, q as u64);

    ctx.begin_phase();

    // Host mirror of the whole matrix (one read per panel; charges are
    // issued explicitly below, as in the 1D path's per-panel mirror).
    let mut host = a.mirror_host()?;

    let dev = |r: usize, c: usize| grid.device_of(r, c);
    let row_members: Vec<Vec<usize>> =
        (0..p).map(|r| (0..q).map(|c| dev(r, c)).collect()).collect();
    // Per grid-row/-column local extents (the 2D shard shape).
    let seg_rows: Vec<usize> = (0..p).map(|r| grid.row_dim().local_extent(r)).collect();
    let loc_cols: Vec<usize> = (0..q).map(|c| grid.col_dim().local_extent(c)).collect();
    let cd = grid.col_dim();
    // Columns of each grid column group, in local storage order.
    let group_cols: Vec<Vec<usize>> = (0..q)
        .map(|c| {
            let mut v = Vec::new();
            for lj in 0..cd.count(c) {
                let tc = cd.at(c, lj);
                for jj in 0..cd.tile_len(tc) {
                    v.push(cd.tile_start(tc) + jj);
                }
            }
            v
        })
        .collect();

    // ---- Stage 1: Householder tridiagonalization on the grid.
    let mut reflectors: Vec<(Vec<S>, S)> = Vec::new();
    for k in 0..n.saturating_sub(2) {
        // Column k lives on the P devices of grid column `ck`.
        let ck = cd.owner(k / cd.tile());
        let ak = host.col(k).to_vec();

        let mut xnorm_sq = <S::Real as RealScalar>::rzero();
        for i in (k + 1)..n {
            xnorm_sq = xnorm_sq + ak[i].abs_sqr();
        }
        // Reflector formation: each column-group member scans its row
        // segment; the scalar norm allreduce rides the u broadcast.
        for r in 0..p {
            ctx.charge_device_time(
                dev(r, ck),
                ctx.model.blas2_time(((2 * (n - k) * esize).div_ceil(p)) as u64),
                0,
            )?;
        }
        let xnorm = xnorm_sq.rsqrt_val();
        if xnorm.to_f64() == 0.0 {
            reflectors.push((vec![S::zero(); n], S::zero()));
            continue;
        }
        let alpha = ak[k + 1];
        let aabs = alpha.abs();
        let phase = if aabs.to_f64() == 0.0 {
            S::one()
        } else {
            alpha * S::from_real(<S::Real as RealScalar>::rone() / aabs)
        };
        let beta = -phase * S::from_real(xnorm);
        let mut u = vec![S::zero(); n];
        let mut unorm_sq = <S::Real as RealScalar>::rzero();
        for i in (k + 1)..n {
            let ui = if i == k + 1 { ak[i] - beta } else { ak[i] };
            u[i] = ui;
            unorm_sq = unorm_sq + ui.abs_sqr();
        }
        if unorm_sq.to_f64() == 0.0 {
            reflectors.push((u, S::zero()));
            continue;
        }
        let tau = S::from_real(<S::Real as RealScalar>::from_f64(2.0) / unorm_sq);

        // u is born row-distributed: each of the P column-group members
        // broadcasts its row segment along its own grid row — P
        // parallel group collectives of ≈ n/P words (vs one owner
        // pushing n words in 1D).
        for r in 0..p {
            ctx.charge_row_ring_broadcast(dev(r, ck), &row_members[r], seg_rows[r] * esize)?;
        }

        // Distributed matvec A·u: each device contracts its block;
        // partial row segments reduce along grid rows to the owner
        // column group.
        let mut au = vec![S::zero(); n];
        for c in 0..q {
            let mut partial = vec![S::zero(); n];
            for &g in &group_cols[c] {
                let ug = u[g];
                if ug == S::zero() {
                    continue;
                }
                let colg = host.col(g);
                for i in 0..n {
                    partial[i] += colg[i] * ug;
                }
            }
            for r in 0..p {
                let blk = seg_rows[r] * loc_cols[c];
                ctx.charge_device_time(
                    dev(r, c),
                    ctx.model.blas2_time((blk * esize) as u64),
                    (2 * blk) as u64,
                )?;
                if c != ck {
                    ctx.charge_ring_p2p(RingAxis::Row, dev(r, c), dev(r, ck), seg_rows[r] * esize)?;
                }
            }
            for i in 0..n {
                au[i] += partial[i];
            }
        }
        // w fans back out the same way: P parallel row-group segments.
        for r in 0..p {
            ctx.charge_row_ring_broadcast(dev(r, ck), &row_members[r], seg_rows[r] * esize)?;
        }

        let mut uhau = S::zero();
        for i in (k + 1)..n {
            uhau += u[i].conj() * au[i];
        }
        let half = S::from_f64(0.5);
        let mut w = vec![S::zero(); n];
        for i in 0..n {
            w[i] = tau * au[i] - half * tau * tau * uhau * u[i];
        }

        // Rank-2 update, charged per device block.
        for c in 0..q {
            for &g in &group_cols[c] {
                let wg = w[g].conj();
                let ug = u[g].conj();
                let colg = host.col_mut(g);
                if wg != S::zero() || ug != S::zero() {
                    for i in 0..n {
                        colg[i] -= u[i] * wg + w[i] * ug;
                    }
                }
            }
            for r in 0..p {
                let blk = seg_rows[r] * loc_cols[c];
                ctx.charge_device_time(
                    dev(r, c),
                    ctx.model.blas2_time((2 * blk * esize) as u64),
                    (4 * blk) as u64,
                )?;
            }
        }

        reflectors.push((u, tau));
    }

    // Tridiagonal extraction + realifying phase diagonal.
    let mut d_diag = vec![<S::Real as RealScalar>::rzero(); n];
    let mut e_sub = vec![<S::Real as RealScalar>::rzero(); n.saturating_sub(1)];
    let mut phases = vec![S::one(); n];
    {
        let mut ph = S::one();
        for i in 0..n {
            d_diag[i] = host[(i, i)].re();
        }
        for k in 0..n.saturating_sub(1) {
            let ek = host[(k + 1, k)];
            let eabs = ek.abs();
            e_sub[k] = eabs;
            let phase = if eabs.to_f64() == 0.0 {
                S::one()
            } else {
                ek * S::from_real(<S::Real as RealScalar>::rone() / eabs)
            };
            ph = ph * phase;
            phases[k + 1] = ph;
        }
    }

    // ---- Stage 2: tridiagonal QL on the lead device (unchanged).
    let tri = Tridiagonal { d: d_diag, e: e_sub };
    let mut z = Matrix::<S>::eye(n);
    let values = tql2(&tri, &mut z)?;
    ctx.charge_device_time(0, ctx.model.blas2_time((6 * n * n * esize) as u64), (6 * n * n * n) as u64)?;
    ctx.charge_broadcast(0, n * n.div_ceil(ndev) * esize)?;

    // ---- Stage 3: back-transform V = (H₀···H_{n-3})·D·Z.
    let nrefl = reflectors.len();
    for (c, cols) in group_cols.iter().enumerate() {
        for &g in cols {
            let dst = host.col_mut(g);
            for i in 0..n {
                dst[i] = phases[i] * z[(i, g)];
            }
            for (u, tau) in reflectors.iter().rev() {
                if *tau == S::zero() {
                    continue;
                }
                let mut uhv = S::zero();
                for i in 0..n {
                    uhv += u[i].conj() * dst[i];
                }
                let t = *tau * uhv;
                for i in 0..n {
                    dst[i] -= u[i] * t;
                }
            }
        }
        for r in 0..p {
            let blk = seg_rows[r] * loc_cols[c];
            ctx.charge_device_time(
                dev(r, c),
                ctx.model.blas2_time((4 * blk * esize) as u64) * nrefl.max(1) as f64,
                (4 * blk * nrefl) as u64,
            )?;
        }
        // Column-split reflector applications need their uᴴv partial
        // dot products reduced along the grid column; charged per
        // blocked group of tile_c reflectors (WY accumulation), so the
        // per-reflector latency amortizes.
        if p > 1 && nrefl > 0 {
            let blocks = nrefl.div_ceil(grid.tile_c().max(1));
            for r in 1..p {
                for _ in 0..blocks {
                    ctx.charge_ring_p2p(RingAxis::Col, dev(r, c), dev(0, c), loc_cols[c] * esize)?;
                }
            }
        }
    }

    a.write_back_host(&host)?;
    let _ = ctx.end_phase();
    Ok(values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::GpuCostModel;
    use crate::device::SimNode;
    use crate::layout::BlockCyclic1D;
    use crate::linalg::{syevd_host, tol_for, FrobNorm};
    use crate::scalar::{c64, Scalar};
    use crate::solver::{Ctx, SolverBackend};
    use crate::tile::{Layout1D, LayoutKind};

    fn run_syevd<S: Scalar>(n: usize, tile: usize, ndev: usize, seed: u64) {
        let node = SimNode::new_uniform(ndev, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<S>::Native;
        let ctx = Ctx::new(&node, &model, &backend);

        let a = Matrix::<S>::hermitian_random(n, seed);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        let vals = syevd_dist(&ctx, &mut dm).unwrap();
        let vecs = dm.gather().unwrap();
        check_eigen(&a, &vals, &vecs, n, &format!("n={n} T={tile} d={ndev}"));
    }

    fn check_eigen<S: Scalar>(a: &Matrix<S>, vals: &[S::Real], vecs: &Matrix<S>, n: usize, what: &str) {
        // A·V = V·Λ
        let av = a.matmul(vecs);
        let mut vl = vecs.clone();
        for j in 0..n {
            let lam = S::from_real(vals[j]);
            for i in 0..n {
                let v = vl[(i, j)] * lam;
                vl[(i, j)] = v;
            }
        }
        let tol = tol_for::<S>(n) * 20.0;
        assert!(av.rel_err(&vl) < tol, "A·V != V·Λ ({what} {:?}): {}", S::DTYPE, av.rel_err(&vl));
        // Orthonormal columns.
        let vhv = vecs.adjoint().matmul(vecs);
        assert!(vhv.rel_err(&Matrix::eye(n)) < tol);
        // Ascending and matching the host oracle.
        let host = syevd_host(a).unwrap();
        for i in 0..n {
            assert!(
                (vals[i].to_f64() - host.values[i].to_f64()).abs()
                    < tol * host.values[n - 1].to_f64().abs().max(1.0),
                "eigenvalue {i} mismatch ({what})"
            );
        }
    }

    fn run_syevd_grid<S: Scalar>(n: usize, tr: usize, tc: usize, p: usize, q: usize, seed: u64) {
        let node = SimNode::new_uniform(p * q, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<S>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<S>::hermitian_random(n, seed);
        let lay = LayoutKind::Grid(crate::layout::BlockCyclic2D::new(n, n, tr, tc, p, q).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        let vals = syevd_dist(&ctx, &mut dm).unwrap();
        let vecs = dm.gather().unwrap();
        check_eigen(&a, &vals, &vecs, n, &format!("grid n={n} {tr}x{tc} {p}x{q}"));
    }

    #[test]
    fn syevd_f64_paper_case() {
        run_syevd::<f64>(24, 4, 4, 1); // Fig. 3c dtype
    }

    #[test]
    fn syevd_f64_ragged() {
        run_syevd::<f64>(21, 4, 3, 2);
    }

    #[test]
    fn syevd_c128() {
        run_syevd::<c64>(18, 3, 2, 3);
    }

    #[test]
    fn syevd_f32() {
        run_syevd::<f32>(12, 2, 2, 4);
    }

    #[test]
    fn syevd_single_device() {
        run_syevd::<f64>(16, 4, 1, 5);
    }

    #[test]
    fn syevd_grid_2x2() {
        run_syevd_grid::<f64>(16, 4, 4, 2, 2, 21);
    }

    #[test]
    fn syevd_grid_ragged_and_complex() {
        run_syevd_grid::<f64>(18, 4, 3, 2, 2, 22); // ragged edge tiles
        run_syevd_grid::<c64>(12, 3, 3, 2, 2, 23);
        run_syevd_grid::<f32>(10, 2, 2, 2, 2, 24);
    }

    #[test]
    fn syevd_grid_3x2() {
        run_syevd_grid::<f64>(18, 3, 3, 3, 2, 25);
    }

    #[test]
    fn syevd_p1_grid_bitwise_matches_1d() {
        // Acceptance: a 1×Q grid of full-height tiles must produce
        // bitwise-identical eigenvalues and eigenvectors to the native
        // 1D layout (it runs the same code path on the same storage).
        let (n, t, ndev) = (20usize, 3usize, 4usize);
        let a = Matrix::<f64>::hermitian_random(n, 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;

        let node1 = SimNode::new_uniform(ndev, 1 << 26);
        let ctx1 = Ctx::new(&node1, &model, &backend);
        let l1 = Layout1D::BlockCyclic(BlockCyclic1D::new(n, t, ndev).unwrap());
        let mut d1 = DistMatrix::scatter(&node1, &a, l1).unwrap();
        let v1 = syevd_dist(&ctx1, &mut d1).unwrap();

        let node2 = SimNode::new_uniform(ndev, 1 << 26);
        let ctx2 = Ctx::new(&node2, &model, &backend);
        let l2 = LayoutKind::Grid(crate::layout::BlockCyclic2D::new(n, n, n, t, 1, ndev).unwrap());
        let mut d2 = DistMatrix::scatter(&node2, &a, l2).unwrap();
        let v2 = syevd_dist(&ctx2, &mut d2).unwrap();

        assert_eq!(v1, v2, "P=1 grid changed eigenvalues");
        assert_eq!(
            d1.gather().unwrap().as_slice(),
            d2.gather().unwrap().as_slice(),
            "P=1 grid changed eigenvectors"
        );
        // Same schedule too: identical simulated makespans.
        assert_eq!(node1.sim_time(), node2.sim_time());
    }

    #[test]
    fn syevd_diag_paper_matrix() {
        // diag(1..N): eigenvalues 1..N, eigenvectors ±e_i.
        let n = 16;
        let node = SimNode::new_uniform(4, 1 << 24);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::spd_diag(n);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 2, 4).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        let vals = syevd_dist(&ctx, &mut dm).unwrap();
        for i in 0..n {
            assert!((vals[i] - (i + 1) as f64).abs() < 1e-10);
        }
    }

    #[test]
    fn syevd_charges_all_devices() {
        let node = SimNode::new_uniform(4, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::hermitian_random(32, 6);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(32, 4, 4).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        node.reset_accounting();
        syevd_dist(&ctx, &mut dm).unwrap();
        for d in 0..4 {
            assert!(node.device(d).unwrap().clock().now() > 0.0, "device {d} idle");
        }
        assert!(node.metrics().snapshot().peer_bytes > 0);
    }

    #[test]
    fn syevd_grid_charges_all_devices() {
        let node = SimNode::new_uniform(4, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::hermitian_random(16, 27);
        let lay = LayoutKind::Grid(crate::layout::BlockCyclic2D::new(16, 16, 4, 4, 2, 2).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        node.reset_accounting();
        syevd_dist(&ctx, &mut dm).unwrap();
        for d in 0..4 {
            assert!(node.device(d).unwrap().clock().now() > 0.0, "device {d} idle");
        }
        assert!(node.metrics().snapshot().peer_bytes > 0);
    }

    #[test]
    fn syevd_pipelined_matches_barrier_and_shrinks_timeline() {
        use crate::solver::PipelineConfig;
        let run = |cfg: PipelineConfig| -> (Vec<f64>, Matrix<f64>, f64) {
            let node = SimNode::new_uniform(4, 1 << 26);
            let model = GpuCostModel::h200();
            let backend = SolverBackend::<f64>::Native;
            let a = Matrix::<f64>::hermitian_random(32, 31);
            let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(32, 4, 4).unwrap());
            let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
            node.reset_accounting();
            let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
            let vals = syevd_dist(&ctx, &mut dm).unwrap();
            (vals, dm.gather().unwrap(), node.sim_time())
        };
        let (v_barrier, z_barrier, t_barrier) = run(PipelineConfig::barrier());
        let (v_look, z_look, t_look) = run(PipelineConfig::lookahead(2));
        assert_eq!(v_barrier, v_look, "schedule changed eigenvalues");
        assert_eq!(z_barrier.as_slice(), z_look.as_slice(), "schedule changed eigenvectors");
        assert!(t_look < t_barrier, "pipelined syevd {t_look} !< barrier {t_barrier}");
    }

    #[test]
    fn syevd_grid_pipelined_matches_barrier() {
        use crate::solver::PipelineConfig;
        let run = |cfg: PipelineConfig| -> (Vec<f64>, Matrix<f64>) {
            let node = SimNode::new_uniform(4, 1 << 26);
            let model = GpuCostModel::h200();
            let backend = SolverBackend::<f64>::Native;
            let a = Matrix::<f64>::hermitian_random(16, 33);
            let lay = LayoutKind::Grid(crate::layout::BlockCyclic2D::new(16, 16, 4, 4, 2, 2).unwrap());
            let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
            let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
            let vals = syevd_dist(&ctx, &mut dm).unwrap();
            (vals, dm.gather().unwrap())
        };
        let (v_barrier, z_barrier) = run(PipelineConfig::barrier());
        let (v_look, z_look) = run(PipelineConfig::lookahead(2));
        assert_eq!(v_barrier, v_look, "schedule changed grid eigenvalues");
        assert_eq!(z_barrier.as_slice(), z_look.as_slice(), "schedule changed grid eigenvectors");
    }

    #[test]
    fn syevd_tiny_sizes() {
        run_syevd::<f64>(1, 1, 1, 7);
        run_syevd::<f64>(2, 1, 2, 8);
        run_syevd::<f64>(3, 2, 2, 9);
    }
}
