//! Distributed symmetric/Hermitian eigensolver (the `cusolverMgSyevd`
//! analogue): eigenvalues + eigenvectors of a block-cyclic `DistMatrix`.
//!
//! Three stages, mirroring the classical multi-GPU `syevd` pipeline:
//!
//! 1. **Distributed Householder tridiagonalization.** For each column
//!    `k`: the owner forms the Householder reflector from its column,
//!    broadcasts it; every device contracts its local columns against
//!    the reflector (`A·u`, a BLAS-2 matvec over the cyclic layout),
//!    partial results are all-reduced, and each device applies the
//!    rank-2 update to its own columns. FLOP-parallel but HBM-bound —
//!    which is exactly why the paper's Fig. 3c shows syevd nearly
//!    independent of `T_A`.
//! 2. **Tridiagonal eigensolve** (implicit-shift QL, `tql2`) on the
//!    lead device — small `O(n)` data, `O(n²)`–`O(n³)` flops, serial.
//! 3. **Distributed back-transformation.** The tridiagonal eigenvectors
//!    are scattered column-cyclically; each device applies the stored
//!    reflectors (and the realifying phase diagonal) to its local
//!    columns — embarrassingly parallel rank-1 updates.
//!
//! During the solve each device's panel is mirrored host-side (one read
//! per panel, not per step); all compute is still *charged* to the
//! owning device's timeline, and reflector broadcasts / all-reduces are
//! charged to the NVLink model. See DESIGN.md §Hardware substitution.

use super::Ctx;
use crate::error::{Error, Result};
use crate::linalg::{tql2, Matrix, Tridiagonal};
use crate::scalar::{RealScalar, Scalar};
use crate::tile::DistMatrix;

/// Eigendecomposition in place: on return `a`'s panels hold the
/// eigenvector columns (same block-cyclic layout) and the ascending
/// eigenvalues are returned.
pub fn syevd_dist<S: Scalar>(ctx: &Ctx<'_, S>, a: &mut DistMatrix<S>) -> Result<Vec<S::Real>> {
    use crate::layout::ColumnLayout;
    let lay = *a
        .layout()
        .as_block_cyclic()
        .ok_or_else(|| Error::layout("syevd requires the block-cyclic layout — redistribute first"))?;
    let n = a.rows();
    if n != a.cols() {
        return Err(Error::shape(format!("syevd needs square matrix, got {}x{}", n, a.cols())));
    }
    if n == 0 {
        return Ok(vec![]);
    }
    let ndev = ctx.node.num_devices();
    let esize = std::mem::size_of::<S>();

    // Pipelined contexts route every charge below onto the per-device
    // compute/copy streams (`Ctx::charge_device_time` and friends), so
    // reflector broadcasts overlap the rank-2 updates; barrier contexts
    // keep the seed clock behaviour.
    ctx.begin_phase();

    // Host mirror of each device panel (read once; see module docs).
    let mut panels: Vec<Matrix<S>> = Vec::with_capacity(ndev);
    for d in 0..ndev {
        let lc = lay.local_cols(d);
        panels.push(a.read_block(d, 0, n, 0, lc)?);
    }
    let col = |panels: &[Matrix<S>], g: usize| -> Vec<S> {
        let (d, loc) = lay.place(g);
        panels[d].col(loc).to_vec()
    };

    // ---- Stage 1: Householder tridiagonalization.
    let mut reflectors: Vec<(Vec<S>, S)> = Vec::new(); // (u, tau), u zero above k+1
    for k in 0..n.saturating_sub(2) {
        let owner = lay.owner_of(k);
        let ak = col(&panels, k);

        // Form the reflector on the column's owner.
        let mut xnorm_sq = <S::Real as RealScalar>::rzero();
        for i in (k + 1)..n {
            xnorm_sq = xnorm_sq + ak[i].abs_sqr();
        }
        ctx.charge_device_time(owner, ctx.model.blas2_time((2 * (n - k) * esize) as u64), 0)?;
        let xnorm = xnorm_sq.rsqrt_val();
        if xnorm.to_f64() == 0.0 {
            reflectors.push((vec![S::zero(); n], S::zero()));
            continue;
        }
        let alpha = ak[k + 1];
        let aabs = alpha.abs();
        let phase = if aabs.to_f64() == 0.0 {
            S::one()
        } else {
            alpha * S::from_real(<S::Real as RealScalar>::rone() / aabs)
        };
        let beta = -phase * S::from_real(xnorm);
        let mut u = vec![S::zero(); n];
        let mut unorm_sq = <S::Real as RealScalar>::rzero();
        for i in (k + 1)..n {
            let ui = if i == k + 1 { ak[i] - beta } else { ak[i] };
            u[i] = ui;
            unorm_sq = unorm_sq + ui.abs_sqr();
        }
        if unorm_sq.to_f64() == 0.0 {
            reflectors.push((u, S::zero()));
            continue;
        }
        let tau = S::from_real(<S::Real as RealScalar>::from_f64(2.0) / unorm_sq);

        // Broadcast the reflector to every device.
        ctx.charge_broadcast(owner, (n - k) * esize)?;

        // w = τ·A·u − ½τ²(uᴴAu)·u ; A·u computed as a distributed
        // matvec: each device contracts its local columns, partials are
        // all-reduced on the owner.
        let mut au = vec![S::zero(); n];
        for d in 0..ndev {
            let lc = lay.local_cols(d);
            let pd = &panels[d];
            let mut partial = vec![S::zero(); n];
            for loc in 0..lc {
                let g = lay.global_index(d, loc);
                let ug = u[g];
                if ug == S::zero() {
                    continue;
                }
                let cd = pd.col(loc);
                for i in 0..n {
                    partial[i] += cd[i] * ug;
                }
            }
            // gemv flops: 2·n·lc, bandwidth-bound.
            ctx.charge_device_time(d, ctx.model.blas2_time((n * lc * esize) as u64), (2 * n * lc) as u64)?;
            ctx.charge_p2p(d, owner, n * esize)?; // reduce to owner
            for i in 0..n {
                au[i] += partial[i];
            }
        }
        ctx.charge_broadcast(owner, n * esize)?; // w back out

        let mut uhau = S::zero();
        for i in (k + 1)..n {
            uhau += u[i].conj() * au[i];
        }
        let half = S::from_f64(0.5);
        let mut w = vec![S::zero(); n];
        for i in 0..n {
            w[i] = tau * au[i] - half * tau * tau * uhau * u[i];
        }

        // Rank-2 update of each device's local columns:
        // A[:,g] −= u·conj(w_g) + w·conj(u_g).
        for d in 0..ndev {
            let lc = lay.local_cols(d);
            let pd = &mut panels[d];
            for loc in 0..lc {
                let g = lay.global_index(d, loc);
                let wg = w[g].conj();
                let ug = u[g].conj();
                let cd = pd.col_mut(loc);
                if wg != S::zero() || ug != S::zero() {
                    for i in 0..n {
                        cd[i] -= u[i] * wg + w[i] * ug;
                    }
                }
            }
            ctx.charge_device_time(d, ctx.model.blas2_time((2 * n * lc * esize) as u64), (4 * n * lc) as u64)?;
        }

        reflectors.push((u, tau));
    }

    // Extract the (possibly complex-subdiagonal) tridiagonal, realify
    // via a phase diagonal folded into the back-transform.
    let mut d_diag = vec![<S::Real as RealScalar>::rzero(); n];
    let mut e_sub = vec![<S::Real as RealScalar>::rzero(); n.saturating_sub(1)];
    let mut phases = vec![S::one(); n];
    {
        let mut p = S::one();
        for i in 0..n {
            d_diag[i] = col(&panels, i)[i].re();
        }
        for k in 0..n.saturating_sub(1) {
            let ek = col(&panels, k)[k + 1];
            let eabs = ek.abs();
            e_sub[k] = eabs;
            let phase = if eabs.to_f64() == 0.0 {
                S::one()
            } else {
                ek * S::from_real(<S::Real as RealScalar>::rone() / eabs)
            };
            p = p * phase;
            phases[k + 1] = p;
        }
    }

    // ---- Stage 2: tridiagonal QL on the lead device.
    let tri = Tridiagonal { d: d_diag, e: e_sub };
    let mut z = Matrix::<S>::eye(n);
    let values = tql2(&tri, &mut z)?;
    // QL with eigenvectors is ~6n³ HBM-bound flops on one device; this
    // T_A-independent term dominates syevd (paper Fig. 3c).
    ctx.charge_device_time(0, ctx.model.blas2_time((6 * n * n * esize) as u64), (6 * n * n * n) as u64)?;
    // Scatter the tridiagonal eigenvectors column-cyclically.
    ctx.charge_broadcast(0, n * n.div_ceil(ndev) * esize)?;

    // ---- Stage 3: distributed back-transform V = (H₀···H_{n-3})·D·Z.
    for d in 0..ndev {
        let lc = lay.local_cols(d);
        let pd = &mut panels[d];
        for loc in 0..lc {
            let g = lay.global_index(d, loc);
            let dst = pd.col_mut(loc);
            // D·Z: row i scaled by phases[i].
            for i in 0..n {
                dst[i] = phases[i] * z[(i, g)];
            }
            // Apply reflectors in reverse: v ← v − u·(τ·(uᴴ v)).
            for (u, tau) in reflectors.iter().rev() {
                if *tau == S::zero() {
                    continue;
                }
                let mut uhv = S::zero();
                for i in 0..n {
                    uhv += u[i].conj() * dst[i];
                }
                let t = *tau * uhv;
                for i in 0..n {
                    dst[i] -= u[i] * t;
                }
            }
        }
        ctx.charge_device_time(
            d,
            ctx.model.blas2_time((4 * n * lc * esize) as u64) * reflectors.len().max(1) as f64,
            (4 * n * lc * reflectors.len()) as u64,
        )?;
    }

    // Write the eigenvector panels back to the devices.
    for d in 0..ndev {
        a.write_block(d, 0, 0, &panels[d])?;
    }
    let _ = ctx.end_phase();
    Ok(values)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::GpuCostModel;
    use crate::device::SimNode;
    use crate::layout::BlockCyclic1D;
    use crate::linalg::{syevd_host, tol_for, FrobNorm};
    use crate::scalar::{c64, Scalar};
    use crate::solver::{Ctx, SolverBackend};
    use crate::tile::Layout1D;

    fn run_syevd<S: Scalar>(n: usize, tile: usize, ndev: usize, seed: u64) {
        let node = SimNode::new_uniform(ndev, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<S>::Native;
        let ctx = Ctx::new(&node, &model, &backend);

        let a = Matrix::<S>::hermitian_random(n, seed);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        let vals = syevd_dist(&ctx, &mut dm).unwrap();
        let vecs = dm.gather().unwrap();

        // A·V = V·Λ
        let av = a.matmul(&vecs);
        let mut vl = vecs.clone();
        for j in 0..n {
            let lam = S::from_real(vals[j]);
            for i in 0..n {
                let v = vl[(i, j)] * lam;
                vl[(i, j)] = v;
            }
        }
        let tol = tol_for::<S>(n) * 20.0;
        assert!(av.rel_err(&vl) < tol, "A·V != V·Λ (n={n} T={tile} d={ndev} {:?}): {}", S::DTYPE, av.rel_err(&vl));
        // Orthonormal columns.
        let vhv = vecs.adjoint().matmul(&vecs);
        assert!(vhv.rel_err(&Matrix::eye(n)) < tol);
        // Ascending and matching the host oracle.
        let host = syevd_host(&a).unwrap();
        for i in 0..n {
            assert!(
                (vals[i].to_f64() - host.values[i].to_f64()).abs()
                    < tol * host.values[n - 1].to_f64().abs().max(1.0),
                "eigenvalue {i} mismatch"
            );
        }
    }

    #[test]
    fn syevd_f64_paper_case() {
        run_syevd::<f64>(24, 4, 4, 1); // Fig. 3c dtype
    }

    #[test]
    fn syevd_f64_ragged() {
        run_syevd::<f64>(21, 4, 3, 2);
    }

    #[test]
    fn syevd_c128() {
        run_syevd::<c64>(18, 3, 2, 3);
    }

    #[test]
    fn syevd_f32() {
        run_syevd::<f32>(12, 2, 2, 4);
    }

    #[test]
    fn syevd_single_device() {
        run_syevd::<f64>(16, 4, 1, 5);
    }

    #[test]
    fn syevd_diag_paper_matrix() {
        // diag(1..N): eigenvalues 1..N, eigenvectors ±e_i.
        let n = 16;
        let node = SimNode::new_uniform(4, 1 << 24);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::spd_diag(n);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 2, 4).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        let vals = syevd_dist(&ctx, &mut dm).unwrap();
        for i in 0..n {
            assert!((vals[i] - (i + 1) as f64).abs() < 1e-10);
        }
    }

    #[test]
    fn syevd_charges_all_devices() {
        let node = SimNode::new_uniform(4, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::hermitian_random(32, 6);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(32, 4, 4).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        node.reset_accounting();
        syevd_dist(&ctx, &mut dm).unwrap();
        for d in 0..4 {
            assert!(node.device(d).unwrap().clock().now() > 0.0, "device {d} idle");
        }
        assert!(node.metrics().snapshot().peer_bytes > 0);
    }

    #[test]
    fn syevd_pipelined_matches_barrier_and_shrinks_timeline() {
        use crate::solver::PipelineConfig;
        let run = |cfg: PipelineConfig| -> (Vec<f64>, Matrix<f64>, f64) {
            let node = SimNode::new_uniform(4, 1 << 26);
            let model = GpuCostModel::h200();
            let backend = SolverBackend::<f64>::Native;
            let a = Matrix::<f64>::hermitian_random(32, 31);
            let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(32, 4, 4).unwrap());
            let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
            node.reset_accounting();
            let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
            let vals = syevd_dist(&ctx, &mut dm).unwrap();
            (vals, dm.gather().unwrap(), node.sim_time())
        };
        let (v_barrier, z_barrier, t_barrier) = run(PipelineConfig::barrier());
        let (v_look, z_look, t_look) = run(PipelineConfig::lookahead(2));
        assert_eq!(v_barrier, v_look, "schedule changed eigenvalues");
        assert_eq!(z_barrier.as_slice(), z_look.as_slice(), "schedule changed eigenvectors");
        assert!(t_look < t_barrier, "pipelined syevd {t_look} !< barrier {t_barrier}");
    }

    #[test]
    fn syevd_tiny_sizes() {
        run_syevd::<f64>(1, 1, 1, 7);
        run_syevd::<f64>(2, 1, 2, 8);
        run_syevd::<f64>(3, 2, 2, 9);
    }
}
