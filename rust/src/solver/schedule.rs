//! Lookahead pipeline timelines: per-device compute / panel / copy
//! streams over the simulated clock.
//!
//! The barrier scheduler charges every kernel and copy straight to the
//! owning device's [`crate::device::SimClock`], which serializes panel
//! factorization, peer copies and trailing updates on one timeline per
//! device. The real cuSOLVERMg overlaps them: the panel for step `k+1`
//! is factored on a high-priority stream while step `k`'s trailing
//! GEMMs are still in flight, and workspace broadcasts ride dedicated
//! copy streams (`cudaMemcpyPeerAsync`). This module models exactly
//! that:
//!
//! * three [`Stream`]s per device — `compute` (trailing GEMMs),
//!   `panel` (potf2/trsm, the priority stream) and `copy` (peer
//!   transfers);
//! * event dependencies carried as completion times and replayed with
//!   [`Event::at`] on consumer streams;
//! * a bounded **lookahead depth**: at most `lookahead` panel steps may
//!   run ahead of the trailing-update frontier (the classic right-
//!   looking lookahead parameter; depth 0 degenerates to the barrier
//!   schedule and is represented by *not* building a timeline at all).
//!
//! A timeline is created per [`super::Ctx`]; each distributed routine
//! brackets its work in [`PipelineTimeline::align`] (streams start no
//! earlier than the current device clocks) and
//! [`PipelineTimeline::finish`] (device clocks jump to the stream
//! horizons, and per-phase busy/span counters flow into
//! [`crate::metrics::Metrics`] as the overlap-efficiency numerator and
//! denominator).

use crate::device::{Event, SimNode, Stream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default panel lookahead depth used by the pipelined solvers.
pub const DEFAULT_LOOKAHEAD: usize = 2;

/// Which ring a grid collective travels along — grid **rows** carry
/// panel segments sideways (one ring per grid row, disjoint source
/// links), grid **columns** carry diagonal blocks, transposed panels
/// and partial-result reductions up/down. The split is what shrinks
/// per-panel broadcast volume from `O(n)` devices-wide (the 1D layout)
/// to `O(n/P)` per ring; the two byte counters
/// (`grid_row_bytes`/`grid_col_bytes` in [`crate::metrics::Metrics`])
/// record it.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum RingAxis {
    /// Along a grid row (between grid columns).
    Row,
    /// Along a grid column (between grid rows).
    Col,
}

/// Row/column communicator over a `P × Q` device grid (row-major
/// device ordinals, as [`crate::layout::MatrixLayout`] lays them out):
/// the membership arithmetic behind the per-row / per-column ring
/// collectives of the grid-native solvers. Purely coordinate math — no
/// device state — so it is freely `Copy` into schedule loops.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct GridComm {
    p: usize,
    q: usize,
}

impl GridComm {
    /// Communicator over a `p × q` grid.
    pub fn new(p: usize, q: usize) -> Self {
        debug_assert!(p > 0 && q > 0, "grid dimensions must be positive");
        GridComm { p, q }
    }

    /// Grid rows `P`.
    pub fn p(&self) -> usize {
        self.p
    }

    /// Grid columns `Q`.
    pub fn q(&self) -> usize {
        self.q
    }

    /// Device ordinal of grid coordinate `(r, c)`.
    pub fn device(&self, r: usize, c: usize) -> usize {
        debug_assert!(r < self.p && c < self.q);
        r * self.q + c
    }

    /// Grid coordinate of device ordinal `d`.
    pub fn coords(&self, d: usize) -> (usize, usize) {
        (d / self.q, d % self.q)
    }

    /// The devices of grid row `r` (the row ring's members).
    pub fn row_members(&self, r: usize) -> Vec<usize> {
        (0..self.q).map(|c| self.device(r, c)).collect()
    }

    /// The devices of grid column `c` (the column ring's members).
    pub fn col_members(&self, c: usize) -> Vec<usize> {
        (0..self.p).map(|r| self.device(r, c)).collect()
    }
}

/// How a solver run is scheduled onto the simulated device timelines.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct PipelineConfig {
    /// Number of panel steps allowed to run ahead of the trailing-
    /// update frontier. `0` selects the barrier schedule (every charge
    /// lands directly on the device clock, as the seed solvers did);
    /// `k >= 1` builds a [`PipelineTimeline`] with depth `k`.
    pub lookahead: usize,
}

impl PipelineConfig {
    /// The strict-barrier schedule (the pre-pipelining behaviour).
    pub fn barrier() -> Self {
        PipelineConfig { lookahead: 0 }
    }

    /// Lookahead pipelining with the given panel depth.
    pub fn lookahead(depth: usize) -> Self {
        PipelineConfig { lookahead: depth }
    }

    /// Whether this configuration builds a stream timeline.
    pub fn is_pipelined(self) -> bool {
        self.lookahead > 0
    }
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self::lookahead(DEFAULT_LOOKAHEAD)
    }
}

#[derive(Debug)]
struct DeviceStreams {
    compute: Stream,
    panel: Stream,
    copy: Stream,
}

/// Per-device view of a finished (or in-flight) pipelined schedule —
/// the golden-timeline tests snapshot these.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceTimeline {
    /// Device ordinal.
    pub device: usize,
    /// Completion horizon of the trailing-update stream, seconds.
    pub compute_horizon: f64,
    /// Completion horizon of the panel (priority) stream, seconds.
    pub panel_horizon: f64,
    /// Completion horizon of the copy stream, seconds.
    pub copy_horizon: f64,
    /// Total busy seconds issued onto this device's streams.
    pub busy: f64,
}

/// Busy/span summary of one pipelined phase (one distributed routine).
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct PhaseReport {
    /// Wall span of the phase on the simulated timeline, seconds.
    pub span: f64,
    /// Total busy seconds across all streams of all devices.
    pub busy: f64,
    /// `busy / (ndev * span)` — mean device utilization. Values above
    /// the barrier schedule's utilization are the overlap win.
    pub utilization: f64,
}

/// Stream timelines for one pipelined solver context.
#[derive(Debug)]
pub struct PipelineTimeline {
    devs: Vec<DeviceStreams>,
    busy_ns: Vec<AtomicU64>,
    /// `(phase start seconds, busy_ns total at phase start)`.
    phase: Mutex<(f64, u64)>,
    lookahead: usize,
}

impl PipelineTimeline {
    /// Build a timeline over `node`'s devices, streams seeded at each
    /// device's current clock.
    pub fn new(node: &SimNode, lookahead: usize) -> Self {
        let n = node.num_devices();
        let mut devs = Vec::with_capacity(n);
        for d in 0..n {
            let now = node.device(d).map(|g| g.clock().now()).unwrap_or(0.0);
            let seeded = |dev: usize| {
                let s = Stream::new(dev);
                s.wait_event(Event::at(now));
                s
            };
            devs.push(DeviceStreams { compute: seeded(d), panel: seeded(d), copy: seeded(d) });
        }
        let busy_ns = (0..n).map(|_| AtomicU64::new(0)).collect();
        PipelineTimeline { devs, busy_ns, phase: Mutex::new((0.0, 0)), lookahead }
    }

    /// The configured lookahead depth.
    pub fn lookahead(&self) -> usize {
        self.lookahead
    }

    /// Number of devices covered.
    pub fn num_devices(&self) -> usize {
        self.devs.len()
    }

    /// The trailing-update stream of device `d`.
    pub fn compute(&self, d: usize) -> &Stream {
        &self.devs[d].compute
    }

    /// The panel (priority) stream of device `d`.
    pub fn panel(&self, d: usize) -> &Stream {
        &self.devs[d].panel
    }

    /// The copy stream of device `d`.
    pub fn copy(&self, d: usize) -> &Stream {
        &self.devs[d].copy
    }

    /// Record `seconds` of issued work on device `d` (for utilization).
    pub fn note_busy(&self, d: usize, seconds: f64) {
        self.busy_ns[d].fetch_add((seconds * 1e9).round() as u64, Ordering::Relaxed);
    }

    /// Completion horizon of device `d`: max over its three streams.
    pub fn horizon(&self, d: usize) -> f64 {
        let ds = &self.devs[d];
        ds.compute.horizon().max(ds.panel.horizon()).max(ds.copy.horizon())
    }

    fn busy_total_ns(&self) -> u64 {
        self.busy_ns.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Start a phase: pull every stream forward to its device's current
    /// clock (work already charged to the clocks — scatter, prior
    /// phases, redistribution — cannot be overlapped retroactively) and
    /// mark the phase origin for [`PipelineTimeline::finish`].
    pub fn align(&self, node: &SimNode) {
        let mut t0 = f64::INFINITY;
        for (d, ds) in self.devs.iter().enumerate() {
            let now = node.device(d).map(|g| g.clock().now()).unwrap_or(0.0);
            // A previous phase may have pushed the streams past the
            // clock already; the phase starts at the later of the two.
            let start = now.max(self.horizon(d));
            if start < t0 {
                t0 = start;
            }
            let ev = Event::at(now);
            ds.compute.wait_event(ev);
            ds.panel.wait_event(ev);
            ds.copy.wait_event(ev);
        }
        if !t0.is_finite() {
            t0 = 0.0;
        }
        *self.phase.lock().unwrap() = (t0, self.busy_total_ns());
    }

    /// End a phase: push every device clock to its stream horizon (so
    /// `SimNode::sim_time` reports the pipelined makespan), publish the
    /// phase's busy/span into the node metrics, and return the report.
    pub fn finish(&self, node: &SimNode) -> PhaseReport {
        let n = self.devs.len();
        let mut end = 0.0f64;
        for d in 0..n {
            let h = self.horizon(d);
            if let Ok(g) = node.device(d) {
                g.clock().sync_to(h);
            }
            end = end.max(h);
        }
        let (t0, busy0) = *self.phase.lock().unwrap();
        let busy = self.busy_total_ns().saturating_sub(busy0) as f64 * 1e-9;
        let span = (end - t0).max(0.0);
        let denom = n as f64 * span;
        let utilization = if denom > 0.0 { busy / denom } else { 0.0 };
        node.metrics().add_overlap((busy * 1e9).round() as u64, (denom * 1e9).round() as u64);
        PhaseReport { span, busy, utilization }
    }

    /// Per-device snapshot of the current stream horizons and busy time.
    pub fn snapshot(&self) -> Vec<DeviceTimeline> {
        self.devs
            .iter()
            .enumerate()
            .map(|(d, ds)| DeviceTimeline {
                device: d,
                compute_horizon: ds.compute.horizon(),
                panel_horizon: ds.panel.horizon(),
                copy_horizon: ds.copy.horizon(),
                busy: self.busy_ns[d].load(Ordering::Relaxed) as f64 * 1e-9,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_comm_membership() {
        let gc = GridComm::new(2, 3);
        assert_eq!(gc.device(1, 2), 5);
        assert_eq!(gc.coords(5), (1, 2));
        assert_eq!(gc.row_members(0), vec![0, 1, 2]);
        assert_eq!(gc.row_members(1), vec![3, 4, 5]);
        assert_eq!(gc.col_members(1), vec![1, 4]);
        assert_eq!(gc.p(), 2);
        assert_eq!(gc.q(), 3);
    }

    #[test]
    fn config_defaults_and_modes() {
        assert!(!PipelineConfig::barrier().is_pipelined());
        assert!(PipelineConfig::lookahead(1).is_pipelined());
        assert_eq!(PipelineConfig::default().lookahead, DEFAULT_LOOKAHEAD);
    }

    #[test]
    fn streams_seed_from_device_clocks() {
        let node = SimNode::new_uniform(2, 1 << 20);
        node.device(1).unwrap().clock().advance(5e-6);
        let tl = PipelineTimeline::new(&node, 1);
        assert_eq!(tl.horizon(0), 0.0);
        assert!((tl.horizon(1) - 5e-6).abs() < 1e-12);
    }

    #[test]
    fn finish_pushes_clocks_and_reports_utilization() {
        let node = SimNode::new_uniform(2, 1 << 20);
        let tl = PipelineTimeline::new(&node, 2);
        tl.align(&node);
        tl.compute(0).issue(10e-6);
        tl.note_busy(0, 10e-6);
        tl.panel(0).issue(10e-6); // overlaps on the same device
        tl.note_busy(0, 10e-6);
        tl.compute(1).issue(4e-6);
        tl.note_busy(1, 4e-6);
        let rep = tl.finish(&node);
        assert!((node.device(0).unwrap().clock().now() - 10e-6).abs() < 1e-12);
        assert!((rep.span - 10e-6).abs() < 1e-12);
        assert!((rep.busy - 24e-6).abs() < 1e-12);
        // 24 µs of work in a 2-device × 10 µs window.
        assert!((rep.utilization - 1.2).abs() < 1e-9);
        let m = node.metrics().snapshot();
        assert!(m.overlap_busy_ns > 0 && m.overlap_span_ns > 0);
    }

    #[test]
    fn align_is_monotone_across_phases() {
        let node = SimNode::new_uniform(1, 1 << 20);
        let tl = PipelineTimeline::new(&node, 1);
        tl.align(&node);
        tl.compute(0).issue(3e-6);
        tl.finish(&node);
        // The clock moved; a second phase must start no earlier.
        tl.align(&node);
        let done = tl.compute(0).issue(1e-6);
        assert!((done - 4e-6).abs() < 1e-12, "got {done}");
    }

    #[test]
    fn snapshot_reports_all_devices() {
        let node = SimNode::new_uniform(3, 1 << 20);
        let tl = PipelineTimeline::new(&node, 1);
        tl.copy(2).issue(1e-6);
        let snap = tl.snapshot();
        assert_eq!(snap.len(), 3);
        assert!(snap[2].copy_horizon > 0.0);
        assert_eq!(snap[0].device, 0);
    }
}
