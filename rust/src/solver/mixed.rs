//! Mixed-precision factorization with iterative refinement — the tier
//! that changes *what* is computed rather than *when*.
//!
//! A mixed solve demotes `A`'s shards to the working dtype (f64→f32,
//! c128→c64), runs the **entire** distributed factorization and
//! triangular solves in that dtype — half the GEMM flops and half the
//! panel/ring/fabric bytes, charged on the same integer-ns timelines
//! through `Ctx<S::Lo>` (every charge helper keys off `S::DTYPE` and
//! `size_of::<S>()`, so the halving falls out of the type) — then
//! refines the promoted solution against the full-precision `A`/`b`:
//!
//! ```text
//! L_lo = potrf(demote(A))            (working dtype, distributed)
//! x    = promote(potrs(L_lo, demote(b)))
//! loop: r = b − A·x                  (f64 residual, distributed GEMV)
//!       if ‖r‖/‖b‖ ≤ tol: done
//!       x += promote(potrs(L_lo, demote(r)))
//! ```
//!
//! The refinement contraction factor is ≈ κ(A)·ε_working per iteration,
//! so the planner carries a condition-number budget on the request and
//! only routes Mixed when the estimated iteration count is small (see
//! `Predictor::refine_secs` / `coordinator::plan_dist`). If the cap is
//! hit, the residual stagnates, or the demoted matrix loses positive
//! definiteness, the solve fails with the **typed**
//! [`Error::RefineStalled`] / [`Error::NotPositiveDefinite`] and the
//! caller falls back to the full-precision path — no request is lost.
//!
//! Numerics are host-side and schedule-independent (the same property
//! the full-precision solvers have), so a mixed solve is
//! bitwise-deterministic across barrier/lookahead schedules, grid
//! shapes, and fabrics; the acceptance bar is the *residual*, not
//! bitwise-vs-full-precision.

use super::{potrf_dist, potrs_dist, Ctx, PipelineConfig, SolverBackend};
use crate::costmodel::GpuCostModel;
use crate::device::SimNode;
use crate::error::{Error, Result};
use crate::linalg::{dense_gemm_acc, Matrix};
use crate::obs::{SpanId, TraceId};
use crate::scalar::{c32, c64, demote_slice, promote_slice, DType, Demote, Promote, Scalar};
use crate::tile::{DistMatrix, LayoutKind};
use std::sync::Arc;

/// Default relative-residual tolerance when a request carries none.
pub const DEFAULT_REFINE_TOL: f64 = 1e-10;
/// Default refinement iteration cap before the typed fallback fires.
pub const DEFAULT_REFINE_CAP: usize = 30;

/// Which precision tier a distributed solve runs in. Carried on
/// [`crate::coordinator::DistPlan`] and decided by
/// `coordinator::plan_dist` from the request's tolerance and
/// condition-number budget.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Factor and solve in the request dtype (the baseline path).
    Full,
    /// Factor and solve in the carried working dtype, then iteratively
    /// refine the residual back in the request dtype.
    Mixed(DType),
}

impl Precision {
    /// Whether this is the mixed tier.
    pub fn is_mixed(self) -> bool {
        matches!(self, Precision::Mixed(_))
    }

    /// Short label for decision logs.
    pub fn name(self) -> &'static str {
        match self {
            Precision::Full => "full",
            Precision::Mixed(_) => "mixed",
        }
    }
}

/// Per-request refinement policy.
#[derive(Copy, Clone, Debug)]
pub struct RefineOptions {
    /// Relative-residual target: ‖b − A·x‖_F / ‖b‖_F ≤ tol.
    pub tol: f64,
    /// Correction solves allowed before [`Error::RefineStalled`].
    pub max_iters: usize,
}

impl Default for RefineOptions {
    fn default() -> Self {
        RefineOptions { tol: DEFAULT_REFINE_TOL, max_iters: DEFAULT_REFINE_CAP }
    }
}

/// What a successful mixed solve reports back to the serving layer.
#[derive(Copy, Clone, Debug, Default, PartialEq)]
pub struct MixedReport {
    /// Correction solves performed (0 = the initial solve already met tol).
    pub iters: usize,
    /// Final relative residual.
    pub residual: f64,
    /// Modeled bytes the working dtype saved vs full precision: each
    /// solve's RHS round trip — plus the factor's storage/traffic when
    /// this solve built the factor (cache-hit refines reuse a resident
    /// one, so its n² term is credited only by the solve that factored)
    /// — at `size_of(hi) − size_of(lo)` per element.
    pub bytes_saved: u64,
}

/// Outcome of [`solve_dist_prec`]: which tier actually produced `x`.
#[derive(Copy, Clone, Debug, Default)]
pub struct SolveOutcome {
    /// True when the mixed tier produced the result.
    pub mixed: bool,
    /// True when a mixed attempt failed typed and the full-precision
    /// path produced the result instead.
    pub fell_back: bool,
    /// Refinement statistics (zeroed for pure full-precision solves).
    pub report: MixedReport,
}

/// The execution environment a mixed solve runs in — everything a
/// serving front threads into `Ctx` plus the target layout. One value
/// drives both the working-dtype and the full-precision context, so the
/// fallback replays on the identical schedule.
#[derive(Clone)]
pub struct MixedRun<'a> {
    pub node: &'a SimNode,
    pub model: &'a GpuCostModel,
    pub pipeline: PipelineConfig,
    pub layout: LayoutKind,
    /// Request trace; `(TraceId(0), SpanId(0))` runs untraced.
    pub trace: (TraceId, SpanId),
    /// Panel-boundary preemption hook (see [`Ctx::with_preempt_hook`]).
    pub preempt: Option<Arc<dyn Fn() + Send + Sync>>,
}

impl<'a> MixedRun<'a> {
    /// Plain run: no trace, no preemption.
    pub fn new(
        node: &'a SimNode,
        model: &'a GpuCostModel,
        pipeline: PipelineConfig,
        layout: LayoutKind,
    ) -> Self {
        MixedRun { node, model, pipeline, layout, trace: (TraceId(0), SpanId(0)), preempt: None }
    }

    /// Build a solver context in dtype `T` on this run's schedule.
    pub fn ctx<T: Scalar>(&self) -> Ctx<'a, T> {
        let backend = SolverBackend::<T>::Native;
        let mut ctx = Ctx::with_pipeline(self.node, self.model, &backend, self.pipeline)
            .with_trace(self.trace.0, self.trace.1);
        if let Some(hook) = &self.preempt {
            ctx = ctx.with_preempt_hook(hook.clone());
        }
        ctx
    }

    /// Emit a decision-log entry on the request trace (no-op untraced).
    fn decision(&self, kind: &'static str, detail: String) {
        if self.trace.0 != TraceId(0) {
            self.node.tracer().decision(self.trace.0, self.node.sim_time_ns(), kind, detail);
        }
    }
}

/// Demote a host matrix elementwise to the working dtype.
pub fn demote_matrix<S: Demote>(a: &Matrix<S>) -> Matrix<S::Lo> {
    Matrix::from_vec(a.rows(), a.cols(), demote_slice(a.as_slice()))
}

/// Promote a working-dtype host matrix back to full precision (exact).
pub fn promote_matrix<L: Promote>(a: &Matrix<L>) -> Matrix<L::Hi> {
    Matrix::from_vec(a.rows(), a.cols(), promote_slice(a.as_slice()))
}

/// Demote `A`'s shards and factor them in the working dtype: each
/// device streams its full-precision shard through the cast kernel once
/// (bandwidth-bound, charged at `blas2_time` over the *wide* bytes),
/// the narrow panels stage at half the H2D bytes, and `potrf_dist`
/// runs entirely in `S::Lo`.
fn factor_impl<S: Demote>(run: &MixedRun<'_>, a: &Matrix<S>) -> Result<DistMatrix<S::Lo>> {
    let ctx = run.ctx::<S::Lo>();
    ctx.begin_phase();
    for d in 0..run.node.num_devices() {
        let bytes = run.layout.local_elems(a.rows(), d) * std::mem::size_of::<S>();
        if bytes > 0 {
            ctx.charge_device_time(d, run.model.blas2_time(bytes as u64), 0)?;
        }
    }
    let _ = ctx.end_phase();
    let lo = demote_matrix(a);
    let mut l = DistMatrix::scatter(run.node, &lo, run.layout)?;
    potrf_dist(&ctx, &mut l)?;
    Ok(l)
}

/// Charge one distributed residual GEMV: every device streams its
/// full-precision shard of `A` once (BLAS-2, bandwidth-bound), then the
/// updated iterate synchronizes node-wide from the root.
fn charge_residual<S: Scalar, L: Scalar>(
    ctx: &Ctx<'_, L>,
    layout: LayoutKind,
    n: usize,
    nrhs: usize,
) -> Result<()> {
    let esize_hi = std::mem::size_of::<S>();
    for d in 0..ctx.node.num_devices() {
        let elems = layout.local_elems(n, d);
        if elems > 0 {
            // 2·elems·nrhs multiply-adds over the local shard of A.
            let flops = GpuCostModel::flops_gemm(S::DTYPE, elems, nrhs, 1);
            ctx.charge_device_time(d, ctx.model.blas2_time((elems * esize_hi) as u64), flops)?;
        }
    }
    ctx.charge_broadcast(0, n * nrhs * esize_hi)
}

/// Iteratively refine against the full-precision `A`/`b` using a
/// resident working-dtype factor — also the path a mixed
/// [`crate::coordinator::FactorCache`] hit takes (the factor is reused,
/// the refinement still runs against the f64 right-hand side).
/// `fresh_factor` records whether this solve built the factor: a
/// cache hit reuses a resident one, so only the RHS round trips count
/// toward `bytes_saved`, not the factor's n² term again.
fn refine_impl<S: Demote>(
    run: &MixedRun<'_>,
    l: &DistMatrix<S::Lo>,
    a: &Matrix<S>,
    b: &Matrix<S>,
    opts: RefineOptions,
    fresh_factor: bool,
) -> Result<(Matrix<S>, MixedReport)> {
    let n = a.rows();
    let nrhs = b.cols();
    if b.rows() != n {
        return Err(Error::shape(format!("rhs has {} rows, matrix is {n}x{n}", b.rows())));
    }
    let ctx = run.ctx::<S::Lo>();

    // Initial solve in the working dtype.
    let b_lo = demote_matrix(b);
    let x_lo = potrs_dist(&ctx, l, &b_lo)?;
    let mut x = promote_matrix(&x_lo);

    let bnorm = {
        let nb = b.norm_fro();
        if nb > 0.0 {
            nb
        } else {
            1.0
        }
    };
    let mut iters = 0usize;
    let mut prev = f64::INFINITY;
    let residual = loop {
        // r = b − A·x in full precision, host-side (deterministic,
        // schedule-independent), charged as a distributed GEMV.
        let mut r = b.clone();
        dense_gemm_acc(&mut r, a, &x, -S::one());
        charge_residual::<S, _>(&ctx, run.layout, n, nrhs)?;
        let res = r.norm_fro() / bnorm;
        run.decision(
            "refine",
            format!("iter={iters} residual={res:.3e} tol={:.1e}", opts.tol),
        );
        if res <= opts.tol {
            break res;
        }
        // κ·ε_working ≥ 1 shows up as a non-contracting (or non-finite)
        // residual; bail out typed instead of burning the whole cap.
        if iters >= opts.max_iters || !res.is_finite() || res > prev * 0.9 {
            return Err(Error::RefineStalled { iters, residual: res, tol: opts.tol });
        }
        prev = res;
        let r_lo = demote_matrix(&r);
        let d_lo = potrs_dist(&ctx, l, &r_lo)?;
        let d = promote_matrix(&d_lo);
        x = x.add(&d);
        iters += 1;
    };

    let esize_hi = std::mem::size_of::<S>() as u64;
    let esize_lo = std::mem::size_of::<<S as Demote>::Lo>() as u64;
    let mut saved_elems = (n * nrhs * (iters + 1)) as u64;
    if fresh_factor {
        saved_elems += (n * n) as u64;
    }
    let bytes_saved = (esize_hi - esize_lo) * saved_elems;
    let report = MixedReport { iters, residual, bytes_saved };
    let m = run.node.metrics();
    m.add_mixed_solve();
    m.record_refine_iters(iters as u64);
    m.add_mixed_bytes_saved(bytes_saved);
    Ok((x, report))
}

/// Dispatch from a dtype-generic serving path into the mixed tier,
/// which only exists for the f64-backed dtypes. The narrow dtypes
/// implement it as a typed config error (`CAPABLE = false`) — the
/// planner never routes them to Mixed, so hitting that arm is a bug
/// surfaced loudly rather than silently serving wrong precision.
pub trait MixedCapable: Scalar {
    /// Working scalar of the mixed tier (`Self` for narrow dtypes).
    type Working: Scalar;
    /// Whether a narrower working precision exists for this dtype.
    const CAPABLE: bool;

    /// Demote the host matrix to the working dtype (the MPMD front
    /// demotes **before** the shards fan out, so staging and `cudaIpc`
    /// traffic move working-dtype bytes).
    fn demote_host(a: &Matrix<Self>) -> Result<Matrix<Self::Working>>;

    /// Demote + factor `A` in the working dtype on `run.layout`.
    fn mixed_factor(run: &MixedRun<'_>, a: &Matrix<Self>) -> Result<DistMatrix<Self::Working>>;

    /// Solve + refine against full-precision `A`/`b` with a resident
    /// working-dtype factor. `fresh_factor` says whether this solve
    /// built the factor (`false` on the cache-hit path, where the
    /// report's `bytes_saved` must not re-credit the factor's n² term).
    fn mixed_refine(
        run: &MixedRun<'_>,
        l: &DistMatrix<Self::Working>,
        a: &Matrix<Self>,
        b: &Matrix<Self>,
        opts: RefineOptions,
        fresh_factor: bool,
    ) -> Result<(Matrix<Self>, MixedReport)>;

    /// Factor, solve and refine in one call, freeing the factor.
    fn mixed_potrs(
        run: &MixedRun<'_>,
        a: &Matrix<Self>,
        b: &Matrix<Self>,
        opts: RefineOptions,
    ) -> Result<(Matrix<Self>, MixedReport)> {
        let l = Self::mixed_factor(run, a)?;
        let out = Self::mixed_refine(run, &l, a, b, opts, true);
        l.free()?;
        out
    }
}

macro_rules! impl_mixed_incapable {
    ($t:ty) => {
        impl MixedCapable for $t {
            type Working = $t;
            const CAPABLE: bool = false;

            fn demote_host(_a: &Matrix<Self>) -> Result<Matrix<Self::Working>> {
                Err(Error::config(concat!(
                    "mixed precision has no working dtype narrower than ",
                    stringify!($t)
                )))
            }

            fn mixed_factor(
                _run: &MixedRun<'_>,
                _a: &Matrix<Self>,
            ) -> Result<DistMatrix<Self::Working>> {
                Err(Error::config(concat!(
                    "mixed precision has no working dtype narrower than ",
                    stringify!($t)
                )))
            }

            fn mixed_refine(
                _run: &MixedRun<'_>,
                _l: &DistMatrix<Self::Working>,
                _a: &Matrix<Self>,
                _b: &Matrix<Self>,
                _opts: RefineOptions,
                _fresh_factor: bool,
            ) -> Result<(Matrix<Self>, MixedReport)> {
                Err(Error::config(concat!(
                    "mixed precision has no working dtype narrower than ",
                    stringify!($t)
                )))
            }
        }
    };
}

macro_rules! impl_mixed_capable {
    ($t:ty, $lo:ty) => {
        impl MixedCapable for $t {
            type Working = $lo;
            const CAPABLE: bool = true;

            fn demote_host(a: &Matrix<Self>) -> Result<Matrix<Self::Working>> {
                Ok(demote_matrix(a))
            }

            fn mixed_factor(
                run: &MixedRun<'_>,
                a: &Matrix<Self>,
            ) -> Result<DistMatrix<Self::Working>> {
                factor_impl::<$t>(run, a)
            }

            fn mixed_refine(
                run: &MixedRun<'_>,
                l: &DistMatrix<Self::Working>,
                a: &Matrix<Self>,
                b: &Matrix<Self>,
                opts: RefineOptions,
                fresh_factor: bool,
            ) -> Result<(Matrix<Self>, MixedReport)> {
                refine_impl::<$t>(run, l, a, b, opts, fresh_factor)
            }
        }
    };
}

impl_mixed_incapable!(f32);
impl_mixed_incapable!(c32);
impl_mixed_capable!(f64, f32);
impl_mixed_capable!(c64, c32);

/// One-call front over the precision tiers with the typed fallback
/// wired in: `Precision::Mixed` runs demote → factor → solve → refine
/// and, on [`Error::RefineStalled`] or a demoted-definiteness failure,
/// reruns the full-precision potrf+potrs on the **same** run — so a
/// routed-Mixed request always yields a result. Tests, benches and the
/// workload drivers go through here; the serving fronts inline the same
/// flow around their factor caches.
pub fn solve_dist_prec<S: MixedCapable>(
    run: &MixedRun<'_>,
    precision: Precision,
    a: &Matrix<S>,
    b: &Matrix<S>,
    opts: RefineOptions,
) -> Result<(Matrix<S>, SolveOutcome)> {
    let mut fell_back = false;
    if precision.is_mixed() {
        match S::mixed_potrs(run, a, b, opts) {
            Ok((x, report)) => {
                return Ok((x, SolveOutcome { mixed: true, fell_back: false, report }));
            }
            Err(Error::RefineStalled { iters, residual, tol }) => {
                run.node.metrics().add_mixed_fallback();
                run.decision(
                    "mixed-fallback",
                    format!("refine stalled: iters={iters} residual={residual:.3e} tol={tol:.1e}"),
                );
                fell_back = true;
            }
            Err(Error::NotPositiveDefinite { minor }) => {
                run.node.metrics().add_mixed_fallback();
                run.decision(
                    "mixed-fallback",
                    format!("demoted matrix lost definiteness at minor {minor}"),
                );
                fell_back = true;
            }
            Err(e) => return Err(e),
        }
    }
    let ctx = run.ctx::<S>();
    let mut l = DistMatrix::scatter(run.node, a, run.layout)?;
    potrf_dist(&ctx, &mut l)?;
    let x = potrs_dist(&ctx, &l, b)?;
    l.free()?;
    Ok((x, SolveOutcome { mixed: false, fell_back, report: MixedReport::default() }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layout::{BlockCyclic1D, BlockCyclic2D};
    use crate::scalar::c64;

    fn node4() -> SimNode {
        SimNode::new_uniform(4, 1 << 26)
    }

    fn lay1d(n: usize, tile: usize, ndev: usize) -> LayoutKind {
        LayoutKind::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap())
    }

    #[test]
    fn mixed_f64_meets_tolerance() {
        let node = node4();
        let model = GpuCostModel::h200();
        let n = 48;
        let a = Matrix::<f64>::spd_random_cond(n, 3, 1e3);
        let x_true = Matrix::<f64>::random(n, 2, 4);
        let b = a.matmul(&x_true);
        let run = MixedRun::new(&node, &model, PipelineConfig::barrier(), lay1d(n, 8, 4));
        let opts = RefineOptions { tol: 1e-11, max_iters: 20 };
        let (x, rep) = f64::mixed_potrs(&run, &a, &b, opts).unwrap();
        assert!(rep.residual <= opts.tol, "residual {} > tol", rep.residual);
        assert!(rep.iters >= 1, "f32 factor cannot meet 1e-11 without refinement");
        let mut r = b.clone();
        dense_gemm_acc(&mut r, &a, &x, -1.0);
        assert!(r.norm_fro() / b.norm_fro() <= opts.tol);
        assert_eq!(node.metrics().snapshot().mixed_solves, 1);
    }

    #[test]
    fn cache_hit_refine_does_not_recredit_factor_bytes() {
        let node = node4();
        let model = GpuCostModel::h200();
        let n = 48;
        let a = Matrix::<f64>::spd_random_cond(n, 21, 1e3);
        let b = Matrix::<f64>::random(n, 2, 22);
        let run = MixedRun::new(&node, &model, PipelineConfig::barrier(), lay1d(n, 8, 4));
        let opts = RefineOptions { tol: 1e-10, max_iters: 20 };
        let l = f64::mixed_factor(&run, &a).unwrap();
        let (_, fresh) = f64::mixed_refine(&run, &l, &a, &b, opts, true).unwrap();
        let (_, hit) = f64::mixed_refine(&run, &l, &a, &b, opts, false).unwrap();
        l.free().unwrap();
        // Identical refinement either way; the hit just drops the
        // factor's n² credit (4 bytes/elem saved at f64→f32).
        assert_eq!(hit.iters, fresh.iters);
        let factor_term = 4 * (n * n) as u64;
        assert_eq!(fresh.bytes_saved, hit.bytes_saved + factor_term);
        assert!(hit.bytes_saved > 0, "RHS round trips still count on a hit");
    }

    #[test]
    fn mixed_c128_meets_tolerance() {
        let node = node4();
        let model = GpuCostModel::h200();
        let n = 32;
        let a = Matrix::<c64>::spd_random_cond(n, 5, 1e2);
        let x_true = Matrix::<c64>::random(n, 1, 6);
        let b = a.matmul(&x_true);
        let run = MixedRun::new(&node, &model, PipelineConfig::barrier(), lay1d(n, 8, 4));
        let opts = RefineOptions { tol: 1e-10, max_iters: 20 };
        let (x, rep) = c64::mixed_potrs(&run, &a, &b, opts).unwrap();
        assert!(rep.residual <= opts.tol);
        let mut r = b.clone();
        dense_gemm_acc(&mut r, &a, &x, -c64::one());
        assert!(r.norm_fro() / b.norm_fro() <= opts.tol);
    }

    #[test]
    fn mixed_bitwise_deterministic_across_schedules_and_grids() {
        let n = 48;
        let a = Matrix::<f64>::spd_random_cond(n, 7, 1e4);
        let b = Matrix::<f64>::random(n, 2, 8);
        let opts = RefineOptions { tol: 1e-9, max_iters: 25 };
        let solve = |pipeline: PipelineConfig, layout_of: &dyn Fn() -> LayoutKind| -> Vec<f64> {
            let node = node4();
            let model = GpuCostModel::h200();
            let run = MixedRun::new(&node, &model, pipeline, layout_of());
            let (x, _) = f64::mixed_potrs(&run, &a, &b, opts).unwrap();
            x.into_vec()
        };
        let base = solve(PipelineConfig::barrier(), &|| lay1d(n, 8, 4));
        let look = solve(PipelineConfig::lookahead(2), &|| lay1d(n, 8, 4));
        assert_eq!(base, look, "schedule changed mixed numerics");
        let grid = solve(PipelineConfig::lookahead(2), &|| {
            LayoutKind::Grid(BlockCyclic2D::new(n, n, 8, 8, 2, 2).unwrap())
        });
        assert_eq!(base, grid, "grid shape changed mixed numerics");
    }

    #[test]
    fn refine_cap_gives_typed_stall_and_fallback_recovers() {
        let node = node4();
        let model = GpuCostModel::h200();
        let n = 40;
        // Condition number high enough that f32 refinement cannot reach
        // a deep-f64 tolerance.
        let a = Matrix::<f64>::spd_random_cond(n, 9, 3e8);
        let x_true = Matrix::<f64>::random(n, 1, 10);
        let b = a.matmul(&x_true);
        let run = MixedRun::new(&node, &model, PipelineConfig::barrier(), lay1d(n, 8, 4));
        let opts = RefineOptions { tol: 1e-13, max_iters: 4 };
        match f64::mixed_potrs(&run, &a, &b, opts) {
            Err(Error::RefineStalled { residual, tol, .. }) => {
                assert!(residual > tol);
            }
            other => panic!("expected RefineStalled, got {:?}", other.map(|(_, r)| r)),
        }
        // The one-call front recovers through the full-precision path.
        let (x, outcome) = solve_dist_prec::<f64>(
            &run,
            Precision::Mixed(DType::F32),
            &a,
            &b,
            opts,
        )
        .unwrap();
        assert!(outcome.fell_back && !outcome.mixed);
        let mut r = b.clone();
        dense_gemm_acc(&mut r, &a, &x, -1.0);
        assert!(r.norm_fro() / b.norm_fro() < 1e-10, "fallback result wrong");
        assert_eq!(node.metrics().snapshot().mixed_fallbacks, 1);
    }

    #[test]
    fn narrow_dtypes_are_statically_incapable() {
        assert!(!<f32 as MixedCapable>::CAPABLE);
        assert!(!<c32 as MixedCapable>::CAPABLE);
        assert!(<f64 as MixedCapable>::CAPABLE);
        assert!(<c64 as MixedCapable>::CAPABLE);
        let node = node4();
        let model = GpuCostModel::h200();
        let a = Matrix::<f32>::spd_random(8, 1);
        let b = Matrix::<f32>::ones(8, 1);
        let run = MixedRun::new(&node, &model, PipelineConfig::barrier(), lay1d(8, 2, 4));
        assert!(matches!(
            f32::mixed_potrs(&run, &a, &b, RefineOptions::default()),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn mixed_is_faster_than_full_on_the_clock() {
        let n = 64;
        let a = Matrix::<f64>::spd_random_cond(n, 13, 1e3);
        let b = Matrix::<f64>::ones(n, 1);
        let elapsed = |precision: Precision| -> (Vec<f64>, f64) {
            let node = node4();
            let model = GpuCostModel::h200();
            let run = MixedRun::new(&node, &model, PipelineConfig::lookahead(2), lay1d(n, 16, 4));
            let opts = RefineOptions { tol: 1e-8, max_iters: 10 };
            let (x, out) = solve_dist_prec::<f64>(&run, precision, &a, &b, opts).unwrap();
            assert_eq!(out.mixed, precision.is_mixed());
            (x.into_vec(), node.sim_time())
        };
        let (_, t_full) = elapsed(Precision::Full);
        let (_, t_mixed) = elapsed(Precision::Mixed(DType::F32));
        // At this tiny n launch overheads dominate, so just require the
        // mixed clock not to blow up; the paper-scale ≥25% win is
        // asserted on the Predictor replay in benches/mixed.rs.
        assert!(t_mixed < t_full * 2.0, "mixed {t_mixed} vs full {t_full}");
    }
}
