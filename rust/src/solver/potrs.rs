//! Distributed Cholesky solve (the `cusolverMgPotrs` analogue).
//!
//! Solves `A·X = B` given the distributed factor `L` (block-cyclic, as
//! produced by [`super::potrf_dist`]) and a *replicated* right-hand side
//! (the paper shards `A` with `P("x", None)` and replicates `b` with
//! `P(None, None)`).
//!
//! Both substitution sweeps are pipelined over tile owners: the owner of
//! tile `t` updates the running RHS tail with its panel and hands the
//! tail to the next owner — a software pipeline over the NVLink ring,
//! which is how a 1D-cyclic triangular solve avoids broadcasting whole
//! panels. The solved tile blocks (`tk × nrhs`) are broadcast at the
//! end so every device's replica of `x` is consistent, matching the
//! replicated output spec.

use super::{Ctx, GridComm, RingAxis};
use crate::costmodel::GpuCostModel;
use crate::error::{Error, Result};
use crate::layout::{BlockCyclic2D, MatrixLayout};
use crate::linalg::Matrix;
use crate::scalar::Scalar;
use crate::tile::DistMatrix;

/// Solve `L·Lᴴ·X = B` for replicated `B` (host-mirrored `n × nrhs`).
/// Dispatches on the factor's layout: columnar (and `P = 1` grids) run
/// the owner-to-owner software pipeline; `P × Q` grids run grid-native
/// ([`potrs_dist_grid`]) with the tail updates split across grid rows.
pub fn potrs_dist<S: Scalar>(
    ctx: &Ctx<'_, S>,
    l: &DistMatrix<S>,
    b: &Matrix<S>,
) -> Result<Matrix<S>> {
    if l.layout().compat_1d(l.rows()).is_none() {
        if let Some(grid) = l.layout().grid2d().copied() {
            return potrs_dist_grid(ctx, l, b, grid);
        }
    }
    // Compatibility path: a 1D block-cyclic handle, or a P=1 grid whose
    // storage is bitwise columnar (see `LayoutKind::compat_1d`).
    let lay = l
        .layout()
        .compat_1d(l.rows())
        .ok_or_else(|| Error::layout("potrs requires a block-cyclic column layout — redistribute first"))?;
    let n = l.rows();
    if b.rows() != n {
        return Err(Error::shape(format!("rhs has {} rows, matrix is {n}x{n}", b.rows())));
    }
    let nrhs = b.cols();
    let ntiles = lay.num_tiles();
    let esize = std::mem::size_of::<S>();

    // Pipelined contexts route the per-tile trsm/gemm charges onto the
    // compute streams and the tail hand-offs onto the copy streams (see
    // `Ctx::charge_p2p`), overlapping the two sweeps' communication
    // with compute; barrier contexts keep the seed clock behaviour.
    ctx.begin_phase();
    let mut y = b.clone();

    // ---- Forward sweep: L·Y = B, pipelined tile-owner to tile-owner.
    for t in 0..ntiles {
        let owner = lay.owner_of_tile(t);
        let k0 = lay.tile_start(t);
        let tk = lay.tile_cols(t);
        let loc0 = lay.tile_local_offset(t);
        let k1 = k0 + tk;

        let lkk = l.read_block(owner, k0, tk, loc0, tk)?;
        let yk = y.submatrix(k0, 0, tk, nrhs);
        let solved = ctx.kernels.trsm_llnn(&lkk, &yk)?;
        ctx.charge_panel(owner, GpuCostModel::flops_trsm(S::DTYPE, tk, nrhs, tk))?;
        y.set_submatrix(k0, 0, &solved);

        let below = n - k1;
        if below > 0 {
            // Tail update with this owner's panel: y[k1..] -= L[k1.., t]·y_t.
            let panel = l.read_block(owner, k1, below, loc0, tk)?;
            let mut tail = y.submatrix(k1, 0, below, nrhs);
            ctx.kernels.gemm_nn(&mut tail, &panel, &solved, -S::one())?;
            ctx.charge_gemm(owner, below, nrhs, tk)?;
            y.set_submatrix(k1, 0, &tail);
            // Hand the running tail to the next tile's owner.
            let next_owner = lay.owner_of_tile(t + 1);
            ctx.charge_p2p(owner, next_owner, below * nrhs * esize)?;
        }
    }

    // ---- Backward sweep: Lᴴ·X = Y, pipelined in reverse.
    let mut x = y;
    for t in (0..ntiles).rev() {
        let owner = lay.owner_of_tile(t);
        let k0 = lay.tile_start(t);
        let tk = lay.tile_cols(t);
        let loc0 = lay.tile_local_offset(t);
        let k1 = k0 + tk;
        let below = n - k1;

        let mut xk = x.submatrix(k0, 0, tk, nrhs);
        if below > 0 {
            // x_t -= L[k1.., t]ᴴ · x[k1..]
            let panel = l.read_block(owner, k1, below, loc0, tk)?;
            let xtail = x.submatrix(k1, 0, below, nrhs);
            ctx.kernels.gemm_hn(&mut xk, &panel, &xtail, -S::one())?;
            ctx.charge_gemm(owner, tk, nrhs, below)?;
        }
        let lkk = l.read_block(owner, k0, tk, loc0, tk)?;
        let solved = ctx.kernels.trsm_llhn(&lkk, &xk)?;
        ctx.charge_panel(owner, GpuCostModel::flops_trsm(S::DTYPE, tk, nrhs, tk))?;
        x.set_submatrix(k0, 0, &solved);

        if t > 0 {
            // The next (lower-indexed) owner needs the solved tail.
            let prev_owner = lay.owner_of_tile(t - 1);
            ctx.charge_p2p(owner, prev_owner, (n - k0) * nrhs * esize)?;
        }
        // Replicated output: solved block flows to all devices. A pure
        // fan-out — the backward chain's data dependency rides the
        // tail hand-off above — so pipelined contexts keep it off the
        // critical path (see `Ctx::charge_fanout`).
        ctx.charge_fanout(owner, tk * nrhs * esize)?;
    }
    let _ = ctx.end_phase();
    Ok(x)
}

/// Grid-native two-sweep solve over a `P × Q` factor: numerics are the
/// exact 1D kernel sequence computed from a host mirror of `L`
/// (bitwise identical results); the schedule splits every tail update
/// across the `P` row owners of the current tile's grid column, the
/// solved diagonal blocks ride **column rings** to those owners, the
/// running tail hands off **along grid rows** in `P` parallel
/// segments (instead of one `O(n·nrhs)` transfer between single
/// owners), and the backward sweep reduces its partial products up the
/// column ring before each diagonal solve.
fn potrs_dist_grid<S: Scalar>(
    ctx: &Ctx<'_, S>,
    l: &DistMatrix<S>,
    b: &Matrix<S>,
    grid: BlockCyclic2D,
) -> Result<Matrix<S>> {
    let n = l.rows();
    if b.rows() != n {
        return Err(Error::shape(format!("rhs has {} rows, matrix is {n}x{n}", b.rows())));
    }
    if grid.tile_r() != grid.tile_c() {
        return Err(Error::layout(
            "grid-native potrs needs square tiles (tile_r == tile_c) — redistribute first",
        ));
    }
    let nrhs = b.cols();
    let (p, q) = grid.grid();
    let comm = GridComm::new(p, q);
    let rd = grid.row_dim();
    let cd = grid.col_dim();
    let nt = cd.num_tiles();
    let esize = std::mem::size_of::<S>();
    ctx.node.metrics().note_grid_solve(p as u64, q as u64);

    ctx.begin_phase();
    let lmir = l.mirror_host()?;
    let mut y = b.clone();

    // Panel rows below tile t owned by grid row r.
    let seg_below = |t: usize| -> Vec<usize> {
        let mut seg = vec![0usize; p];
        for j in (t + 1)..nt {
            seg[rd.owner(j)] += rd.tile_len(j);
        }
        seg
    };

    // ---- Forward sweep: L·Y = B.
    for t in 0..nt {
        let tk = cd.tile_len(t);
        let k0 = cd.tile_start(t);
        let k1 = k0 + tk;
        let rt = rd.owner(t);
        let ct = cd.owner(t);
        let diag = comm.device(rt, ct);

        let lkk = lmir.submatrix(k0, k0, tk, tk);
        let yk = y.submatrix(k0, 0, tk, nrhs);
        let solved = ctx.kernels.trsm_llnn(&lkk, &yk)?;
        ctx.charge_panel(diag, GpuCostModel::flops_trsm(S::DTYPE, tk, nrhs, tk))?;
        y.set_submatrix(k0, 0, &solved);

        let below = n - k1;
        if below > 0 {
            let seg = seg_below(t);
            // The solved block flows down the column ring to the row
            // owners updating their tail segments.
            let members: Vec<usize> =
                (0..p).filter(|&r| r != rt && seg[r] > 0).map(|r| comm.device(r, ct)).collect();
            ctx.charge_col_ring_broadcast(diag, &members, tk * nrhs * esize)?;
            // Tail update, split across the grid rows (numerics: the
            // exact 1D full-tail GEMM).
            let panel = lmir.submatrix(k1, k0, below, tk);
            let mut tail = y.submatrix(k1, 0, below, nrhs);
            ctx.kernels.gemm_nn(&mut tail, &panel, &solved, -S::one())?;
            for r in 0..p {
                if seg[r] > 0 {
                    ctx.charge_gemm(comm.device(r, ct), seg[r], nrhs, tk)?;
                }
            }
            y.set_submatrix(k1, 0, &tail);
            // Hand the running tail to the next tile's grid column — P
            // parallel row-segment hops instead of one O(n·nrhs) move.
            let cn = cd.owner(t + 1);
            if cn != ct {
                for r in 0..p {
                    if seg[r] > 0 {
                        ctx.charge_ring_p2p(
                            RingAxis::Row,
                            comm.device(r, ct),
                            comm.device(r, cn),
                            seg[r] * nrhs * esize,
                        )?;
                    }
                }
            }
        }
    }

    // ---- Backward sweep: Lᴴ·X = Y.
    let mut x = y;
    for t in (0..nt).rev() {
        let tk = cd.tile_len(t);
        let k0 = cd.tile_start(t);
        let k1 = k0 + tk;
        let rt = rd.owner(t);
        let ct = cd.owner(t);
        let diag = comm.device(rt, ct);
        let below = n - k1;

        let mut xk = x.submatrix(k0, 0, tk, nrhs);
        if below > 0 {
            let seg = seg_below(t);
            // Partial products on the row owners, reduced up the
            // column ring to the diagonal owner.
            let panel = lmir.submatrix(k1, k0, below, tk);
            let xtail = x.submatrix(k1, 0, below, nrhs);
            ctx.kernels.gemm_hn(&mut xk, &panel, &xtail, -S::one())?;
            for r in 0..p {
                if seg[r] > 0 {
                    ctx.charge_gemm(comm.device(r, ct), tk, nrhs, seg[r])?;
                }
            }
            for r in 0..p {
                if r != rt && seg[r] > 0 {
                    ctx.charge_ring_p2p(
                        RingAxis::Col,
                        comm.device(r, ct),
                        diag,
                        tk * nrhs * esize,
                    )?;
                }
            }
        }
        let lkk = lmir.submatrix(k0, k0, tk, tk);
        let solved = ctx.kernels.trsm_llhn(&lkk, &xk)?;
        ctx.charge_panel(diag, GpuCostModel::flops_trsm(S::DTYPE, tk, nrhs, tk))?;
        x.set_submatrix(k0, 0, &solved);

        if t > 0 {
            // The solved tail x[k0..] hands off to the previous tile's
            // grid column as P parallel row segments.
            let cprev = cd.owner(t - 1);
            if cprev != ct {
                let mut rows_ge = vec![0usize; p];
                for j in t..nt {
                    rows_ge[rd.owner(j)] += rd.tile_len(j);
                }
                for r in 0..p {
                    if rows_ge[r] > 0 {
                        ctx.charge_ring_p2p(
                            RingAxis::Row,
                            comm.device(r, ct),
                            comm.device(r, cprev),
                            rows_ge[r] * nrhs * esize,
                        )?;
                    }
                }
            }
        }
        // Replicated output: a pure fan-out, off the critical path
        // under the pipelined schedule (see `Ctx::charge_fanout`).
        ctx.charge_fanout(diag, tk * nrhs * esize)?;
    }
    let _ = ctx.end_phase();
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::GpuCostModel;
    use crate::device::SimNode;
    use crate::layout::BlockCyclic1D;
    use crate::linalg::{self, tol_for, FrobNorm};
    use crate::scalar::{c64, Scalar};
    use crate::solver::{potrf_dist, SolverBackend};
    use crate::tile::Layout1D;

    fn run_potrs<S: Scalar>(n: usize, nrhs: usize, tile: usize, ndev: usize, seed: u64) {
        let node = SimNode::new_uniform(ndev, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<S>::Native;
        let ctx = Ctx::new(&node, &model, &backend);

        let a = Matrix::<S>::spd_random(n, seed);
        let x_true = Matrix::<S>::random(n, nrhs, seed + 1);
        let b = a.matmul(&x_true);

        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        let x = potrs_dist(&ctx, &dm, &b).unwrap();

        assert!(
            x.rel_err(&x_true) < tol_for::<S>(n) * 10.0,
            "potrs wrong (n={n} T={tile} d={ndev} {:?}): {}",
            S::DTYPE,
            x.rel_err(&x_true)
        );
    }

    #[test]
    fn potrs_f64_multi_rhs() {
        run_potrs::<f64>(32, 3, 4, 4, 1);
    }

    #[test]
    fn potrs_f64_ragged() {
        run_potrs::<f64>(29, 2, 4, 3, 2);
    }

    #[test]
    fn potrs_f32_single_rhs() {
        run_potrs::<f32>(16, 1, 4, 2, 3);
    }

    #[test]
    fn potrs_c128() {
        run_potrs::<c64>(24, 2, 4, 4, 4);
    }

    #[test]
    fn potrs_paper_workload() {
        // The paper's benchmark: A = diag(1..N), b = ones.
        let n = 24;
        let node = SimNode::new_uniform(4, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::spd_diag(n);
        let b = Matrix::<f64>::ones(n, 1);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 2, 4).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        let x = potrs_dist(&ctx, &dm, &b).unwrap();
        // Exact solution: x_i = 1/(i+1).
        for i in 0..n {
            assert!((x[(i, 0)] - 1.0 / (i + 1) as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn potrs_matches_host_reference() {
        let n = 20;
        let node = SimNode::new_uniform(2, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::spd_random(n, 9);
        let b = Matrix::<f64>::random(n, 4, 10);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 4, 2).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        let x = potrs_dist(&ctx, &dm, &b).unwrap();
        let l_ref = linalg::potrf(&a).unwrap();
        let x_ref = linalg::potrs_from_chol(&l_ref, &b).unwrap();
        assert!(x.rel_err(&x_ref) < 1e-12);
    }

    #[test]
    fn potrs_pipelined_matches_barrier_and_shrinks_timeline() {
        use crate::solver::PipelineConfig;
        let run = |cfg: PipelineConfig| -> (Matrix<f64>, f64) {
            let node = SimNode::new_uniform(4, 1 << 26);
            let model = GpuCostModel::h200();
            let backend = SolverBackend::<f64>::Native;
            let a = Matrix::<f64>::spd_random(48, 21);
            let b = Matrix::<f64>::random(48, 2, 22);
            let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(48, 4, 4).unwrap());
            let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
            node.reset_accounting();
            let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
            potrf_dist(&ctx, &mut dm).unwrap();
            let x = potrs_dist(&ctx, &dm, &b).unwrap();
            (x, node.sim_time())
        };
        let (x_barrier, t_barrier) = run(PipelineConfig::barrier());
        let (x_look, t_look) = run(PipelineConfig::lookahead(2));
        assert_eq!(x_barrier.as_slice(), x_look.as_slice(), "schedule changed numerics");
        assert!(t_look < t_barrier, "pipelined potrf+potrs {t_look} !< barrier {t_barrier}");
    }

    #[test]
    fn potrs_shape_mismatch() {
        let node = SimNode::new_uniform(2, 1 << 22);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::spd_random(8, 1);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(8, 2, 2).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        let b = Matrix::<f64>::ones(9, 1);
        assert!(potrs_dist(&ctx, &dm, &b).is_err());
    }
}
