//! Distributed Cholesky-based inverse (the `cusolverMgPotri` analogue):
//! `A⁻¹ = L⁻ᴴ·L⁻¹` from the distributed factor `L`.
//!
//! Two phases, both over the block-cyclic layout:
//!
//! 1. **trtri** — `X = L⁻¹` by pipelined forward substitution against
//!    identity column blocks: the owner of row-tile `j` solves its
//!    diagonal block, ships the solved block to the column's owner and
//!    hands the updated running tail down the pipeline (same pattern as
//!    `potrs`, one pipeline per column tile).
//! 2. **lauum** — `A⁻¹ = Xᴴ·X` by panel rounds: the owner of column
//!    tile `ti` broadcasts its packed panel; every device contracts it
//!    against its own tiles (`GEMM_HN`) and writes the `(I, J)` result
//!    block in place. Ascending rounds only ever overwrite rows that
//!    later rounds no longer read, so the product is formed in place.
//!
//! The extra full-matrix workspace `X` is exactly why the paper's §3
//! notes potri "require[s] significantly more workspace memory than
//! potrs" — the capacity tables in the benches read this allocation.

use super::Ctx;
use crate::costmodel::GpuCostModel;
use crate::error::{Error, Result};
use crate::linalg::Matrix;
use crate::scalar::Scalar;
use crate::tile::DistMatrix;

/// Invert in place: on entry `a` holds the distributed factor `L`
/// (from [`super::potrf_dist`]); on return it holds `A⁻¹` (full
/// Hermitian, both triangles).
pub fn potri_dist<S: Scalar>(ctx: &Ctx<'_, S>, a: &mut DistMatrix<S>) -> Result<()> {
    // Compatibility path: a 1D block-cyclic handle, or a P=1 grid whose
    // storage is bitwise columnar (see `LayoutKind::compat_1d`).
    let lay = a
        .layout()
        .compat_1d(a.rows())
        .ok_or_else(|| Error::layout("potri requires a block-cyclic column layout — redistribute first"))?;
    let n = a.rows();
    let ntiles = lay.num_tiles();
    let esize = std::mem::size_of::<S>();

    // ---- Phase 1: X = L⁻¹ into a fresh distributed workspace
    // (the potri workspace highlighted in the paper's §3).
    // Pipelined contexts route the charges below onto the per-device
    // compute/copy streams (see `Ctx`), so the column pipelines of
    // phase 1 and the broadcast rounds of phase 2 overlap.
    ctx.begin_phase();
    let x = DistMatrix::<S>::alloc(ctx.node, n, *a.layout())?;

    for t in 0..ntiles {
        let t_owner = lay.owner_of_tile(t);
        let k0 = lay.tile_start(t);
        let tk = lay.tile_cols(t);
        let t_loc = lay.tile_local_offset(t);

        // Running RHS tail: rows k0..n, width tk. Starts as the identity
        // block at rows k0..k1.
        let mut tail = Matrix::<S>::zeros(n - k0, tk);
        for c in 0..tk {
            tail[(c, c)] = S::one();
        }

        for j in t..ntiles {
            let j_owner = lay.owner_of_tile(j);
            let j0 = lay.tile_start(j);
            let tj = lay.tile_cols(j);
            let j_loc = lay.tile_local_offset(j);
            let j1 = j0 + tj;

            // Solve the diagonal block on j's owner.
            let ljj = a.read_block(j_owner, j0, tj, j_loc, tj)?;
            let bj = tail.submatrix(j0 - k0, 0, tj, tk);
            let zj = ctx.kernels.trsm_llnn(&ljj, &bj)?;
            ctx.charge_panel(j_owner, GpuCostModel::flops_trsm(S::DTYPE, tj, tk, tj))?;

            // Store the solved block at X[j0..j1, tile t] on t's owner.
            x.write_block(t_owner, j0, t_loc, &zj)?;
            ctx.charge_p2p(j_owner, t_owner, tj * tk * esize)?;

            // Update the running tail below and pass it on.
            let below = n - j1;
            if below > 0 {
                let panel = a.read_block(j_owner, j1, below, j_loc, tj)?;
                let mut lower = tail.submatrix(j1 - k0, 0, below, tk);
                ctx.kernels.gemm_nn(&mut lower, &panel, &zj, -S::one())?;
                ctx.charge_gemm(j_owner, below, tk, tj)?;
                tail.set_submatrix(j1 - k0, 0, &lower);
                let next_owner = lay.owner_of_tile(j + 1);
                ctx.charge_p2p(j_owner, next_owner, below * tk * esize)?;
            }
        }
        // Zero above-diagonal rows of X's tile column (X is lower).
        if k0 > 0 {
            x.write_block(t_owner, 0, t_loc, &Matrix::<S>::zeros(k0, tk))?;
        }
    }

    // ---- Phase 2: A⁻¹ = Xᴴ·X in place over `x`, then copy into `a`.
    let ndev = ctx.node.num_devices();
    for ti in 0..ntiles {
        let i_owner = lay.owner_of_tile(ti);
        let k0i = lay.tile_start(ti);
        let tki = lay.tile_cols(ti);
        let i_loc = lay.tile_local_offset(ti);
        let pi_rows = n - k0i;

        // Read the panel BEFORE any round-ti writes, then broadcast.
        let pi = x.read_block(i_owner, k0i, pi_rows, i_loc, tki)?;
        let panel_elems = pi_rows * tki;
        let src_scratch = ctx.node.alloc_scalars::<S>(i_owner, panel_elems)?;
        ctx.node.write_slice(src_scratch, 0, pi.as_slice())?;
        let mut scratch: Vec<Option<crate::device::DevPtr>> = vec![None; ndev];
        for d in 0..ndev {
            if d == i_owner {
                continue;
            }
            let dst = ctx.node.alloc_scalars::<S>(d, panel_elems)?;
            ctx.panel_copy(src_scratch, dst, panel_elems * esize, ctx.device_ready(i_owner))?;
            scratch[d] = Some(dst);
        }

        for tj in 0..ntiles {
            let j_owner = lay.owner_of_tile(tj);
            let k0j = lay.tile_start(tj);
            let tkj = lay.tile_cols(tj);
            let j_loc = lay.tile_local_offset(tj);
            let kmax = k0i.max(k0j);
            let height = n - kmax;

            // A-side: panel rows kmax.. (local copy on j's owner).
            let a_blk = if j_owner == i_owner {
                pi.submatrix(kmax - k0i, 0, height, tki)
            } else {
                let ptr = scratch[j_owner].expect("panel scratch");
                let mut full = vec![S::zero(); panel_elems];
                ctx.node.read_slice(ptr, 0, &mut full)?;
                Matrix::from_vec(pi_rows, tki, full).submatrix(kmax - k0i, 0, height, tki)
            };
            // B-side: X rows kmax.. of tile tj (still unoverwritten).
            let b_blk = x.read_block(j_owner, kmax, height, j_loc, tkj)?;
            let mut c = Matrix::<S>::zeros(tki, tkj);
            ctx.kernels.gemm_hn(&mut c, &a_blk, &b_blk, S::one())?;
            ctx.charge_gemm(j_owner, tki, tkj, height)?;
            // Write result rows k0i..k1i of tile tj.
            x.write_block(j_owner, k0i, j_loc, &c)?;
        }

        ctx.node.free(src_scratch)?;
        for s in scratch.into_iter().flatten() {
            ctx.node.free(s)?;
        }
    }

    // Copy the inverse into `a`'s panels (local device copies).
    for d in 0..ndev {
        let lc = lay_local_cols(&lay, d);
        if lc == 0 {
            continue;
        }
        ctx.panel_copy(x.panels()[d], a.panels()[d], n * lc * esize, ctx.device_ready(d))?;
    }
    x.free()?;
    let _ = ctx.end_phase();
    Ok(())
}

fn lay_local_cols(lay: &crate::layout::BlockCyclic1D, d: usize) -> usize {
    use crate::layout::ColumnLayout;
    lay.local_cols(d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::GpuCostModel;
    use crate::device::SimNode;
    use crate::layout::BlockCyclic1D;
    use crate::linalg::{tol_for, FrobNorm};
    use crate::scalar::{c64, Scalar};
    use crate::solver::{potrf_dist, SolverBackend};
    use crate::tile::Layout1D;

    fn run_potri<S: Scalar>(n: usize, tile: usize, ndev: usize, seed: u64) {
        let node = SimNode::new_uniform(ndev, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<S>::Native;
        let ctx = Ctx::new(&node, &model, &backend);

        let a = Matrix::<S>::spd_random(n, seed);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        potri_dist(&ctx, &mut dm).unwrap();
        let inv = dm.gather().unwrap();

        let ident = a.matmul(&inv);
        assert!(
            ident.rel_err(&Matrix::eye(n)) < tol_for::<S>(n) * 10.0,
            "A·A⁻¹ != I (n={n} T={tile} d={ndev} {:?}): {}",
            S::DTYPE,
            ident.rel_err(&Matrix::eye(n))
        );
        // Result must be Hermitian (full storage).
        assert!(inv.rel_err(&inv.adjoint()) < tol_for::<S>(n) * 10.0);
    }

    #[test]
    fn potri_f64() {
        run_potri::<f64>(24, 4, 4, 1);
    }

    #[test]
    fn potri_f64_ragged() {
        run_potri::<f64>(27, 5, 3, 2);
    }

    #[test]
    fn potri_c128_paper_case() {
        // Fig. 3b benchmarks potri on complex128.
        run_potri::<c64>(20, 4, 4, 3);
    }

    #[test]
    fn potri_f32() {
        run_potri::<f32>(16, 4, 2, 4);
    }

    #[test]
    fn potri_single_device() {
        run_potri::<f64>(12, 3, 1, 5);
    }

    #[test]
    fn potri_diag_is_reciprocal() {
        // diag(1..N)⁻¹ = diag(1, 1/2, ..., 1/N) — the paper's matrix.
        let n = 12;
        let node = SimNode::new_uniform(2, 1 << 24);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::spd_diag(n);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 3, 2).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        potri_dist(&ctx, &mut dm).unwrap();
        let inv = dm.gather().unwrap();
        for i in 0..n {
            assert!((inv[(i, i)] - 1.0 / (i + 1) as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn potri_pipelined_matches_barrier_and_shrinks_timeline() {
        use crate::solver::PipelineConfig;
        let run = |cfg: PipelineConfig| -> (Matrix<f64>, f64) {
            let node = SimNode::new_uniform(4, 1 << 26);
            let model = GpuCostModel::h200();
            let backend = SolverBackend::<f64>::Native;
            let a = Matrix::<f64>::spd_random(32, 23);
            let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(32, 4, 4).unwrap());
            let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
            node.reset_accounting();
            let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
            potrf_dist(&ctx, &mut dm).unwrap();
            potri_dist(&ctx, &mut dm).unwrap();
            (dm.gather().unwrap(), node.sim_time())
        };
        let (inv_barrier, t_barrier) = run(PipelineConfig::barrier());
        let (inv_look, t_look) = run(PipelineConfig::lookahead(2));
        assert_eq!(inv_barrier.as_slice(), inv_look.as_slice(), "schedule changed numerics");
        assert!(t_look < t_barrier, "pipelined potri {t_look} !< barrier {t_barrier}");
    }

    #[test]
    fn potri_no_leaked_workspace() {
        let node = SimNode::new_uniform(2, 1 << 24);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::spd_random(16, 6);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(16, 4, 2).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        potri_dist(&ctx, &mut dm).unwrap();
        for rep in node.memory_reports() {
            assert_eq!(rep.allocations, 1, "workspace must be freed");
        }
        // Peak usage must reflect the X workspace (≈2× the panel).
        assert!(node.memory_reports()[0].peak_used >= 2 * node.memory_reports()[0].used);
    }
}
