//! Distributed Cholesky-based inverse (the `cusolverMgPotri` analogue):
//! `A⁻¹ = L⁻ᴴ·L⁻¹` from the distributed factor `L`.
//!
//! Two phases, both over the block-cyclic layout:
//!
//! 1. **trtri** — `X = L⁻¹` by pipelined forward substitution against
//!    identity column blocks: the owner of row-tile `j` solves its
//!    diagonal block, ships the solved block to the column's owner and
//!    hands the updated running tail down the pipeline (same pattern as
//!    `potrs`, one pipeline per column tile).
//! 2. **lauum** — `A⁻¹ = Xᴴ·X` by panel rounds: the owner of column
//!    tile `ti` broadcasts its packed panel; every device contracts it
//!    against its own tiles (`GEMM_HN`) and writes the `(I, J)` result
//!    block in place. Ascending rounds only ever overwrite rows that
//!    later rounds no longer read, so the product is formed in place.
//!
//! The extra full-matrix workspace `X` is exactly why the paper's §3
//! notes potri "require[s] significantly more workspace memory than
//! potrs" — the capacity tables in the benches read this allocation.

use super::{Ctx, GridComm, RingAxis};
use crate::costmodel::GpuCostModel;
use crate::error::{Error, Result};
use crate::layout::{BlockCyclic2D, MatrixLayout};
use crate::linalg::Matrix;
use crate::scalar::Scalar;
use crate::tile::DistMatrix;

/// Invert in place: on entry `a` holds the distributed factor `L`
/// (from [`super::potrf_dist`]); on return it holds `A⁻¹` (full
/// Hermitian, both triangles). Dispatches on the layout: columnar (and
/// `P = 1` grids) run the owner-pipelined path; `P × Q` grids run
/// grid-native ([`potri_dist_grid`]) with row-split trtri pipelines
/// and row-ring lauum panel broadcasts.
pub fn potri_dist<S: Scalar>(ctx: &Ctx<'_, S>, a: &mut DistMatrix<S>) -> Result<()> {
    if a.layout().compat_1d(a.rows()).is_none() {
        if let Some(grid) = a.layout().grid2d().copied() {
            return potri_dist_grid(ctx, a, grid);
        }
    }
    // Compatibility path: a 1D block-cyclic handle, or a P=1 grid whose
    // storage is bitwise columnar (see `LayoutKind::compat_1d`).
    let lay = a
        .layout()
        .compat_1d(a.rows())
        .ok_or_else(|| Error::layout("potri requires a block-cyclic column layout — redistribute first"))?;
    let n = a.rows();
    let ntiles = lay.num_tiles();
    let esize = std::mem::size_of::<S>();

    // ---- Phase 1: X = L⁻¹ into a fresh distributed workspace
    // (the potri workspace highlighted in the paper's §3).
    // Pipelined contexts route the charges below onto the per-device
    // compute/copy streams (see `Ctx`), so the column pipelines of
    // phase 1 and the broadcast rounds of phase 2 overlap.
    ctx.begin_phase();
    let x = DistMatrix::<S>::alloc(ctx.node, n, *a.layout())?;

    for t in 0..ntiles {
        let t_owner = lay.owner_of_tile(t);
        let k0 = lay.tile_start(t);
        let tk = lay.tile_cols(t);
        let t_loc = lay.tile_local_offset(t);

        // Running RHS tail: rows k0..n, width tk. Starts as the identity
        // block at rows k0..k1.
        let mut tail = Matrix::<S>::zeros(n - k0, tk);
        for c in 0..tk {
            tail[(c, c)] = S::one();
        }

        for j in t..ntiles {
            let j_owner = lay.owner_of_tile(j);
            let j0 = lay.tile_start(j);
            let tj = lay.tile_cols(j);
            let j_loc = lay.tile_local_offset(j);
            let j1 = j0 + tj;

            // Solve the diagonal block on j's owner.
            let ljj = a.read_block(j_owner, j0, tj, j_loc, tj)?;
            let bj = tail.submatrix(j0 - k0, 0, tj, tk);
            let zj = ctx.kernels.trsm_llnn(&ljj, &bj)?;
            ctx.charge_panel(j_owner, GpuCostModel::flops_trsm(S::DTYPE, tj, tk, tj))?;

            // Store the solved block at X[j0..j1, tile t] on t's owner.
            x.write_block(t_owner, j0, t_loc, &zj)?;
            ctx.charge_p2p(j_owner, t_owner, tj * tk * esize)?;

            // Update the running tail below and pass it on.
            let below = n - j1;
            if below > 0 {
                let panel = a.read_block(j_owner, j1, below, j_loc, tj)?;
                let mut lower = tail.submatrix(j1 - k0, 0, below, tk);
                ctx.kernels.gemm_nn(&mut lower, &panel, &zj, -S::one())?;
                ctx.charge_gemm(j_owner, below, tk, tj)?;
                tail.set_submatrix(j1 - k0, 0, &lower);
                let next_owner = lay.owner_of_tile(j + 1);
                ctx.charge_p2p(j_owner, next_owner, below * tk * esize)?;
            }
        }
        // Zero above-diagonal rows of X's tile column (X is lower).
        if k0 > 0 {
            x.write_block(t_owner, 0, t_loc, &Matrix::<S>::zeros(k0, tk))?;
        }
    }

    // ---- Phase 2: A⁻¹ = Xᴴ·X in place over `x`, then copy into `a`.
    let ndev = ctx.node.num_devices();
    for ti in 0..ntiles {
        let i_owner = lay.owner_of_tile(ti);
        let k0i = lay.tile_start(ti);
        let tki = lay.tile_cols(ti);
        let i_loc = lay.tile_local_offset(ti);
        let pi_rows = n - k0i;

        // Read the panel BEFORE any round-ti writes, then broadcast.
        let pi = x.read_block(i_owner, k0i, pi_rows, i_loc, tki)?;
        let panel_elems = pi_rows * tki;
        let src_scratch = ctx.node.alloc_scalars::<S>(i_owner, panel_elems)?;
        ctx.node.write_slice(src_scratch, 0, pi.as_slice())?;
        let mut scratch: Vec<Option<crate::device::DevPtr>> = vec![None; ndev];
        for d in 0..ndev {
            if d == i_owner {
                continue;
            }
            let dst = ctx.node.alloc_scalars::<S>(d, panel_elems)?;
            ctx.panel_copy(src_scratch, dst, panel_elems * esize, ctx.device_ready(i_owner))?;
            scratch[d] = Some(dst);
        }

        for tj in 0..ntiles {
            let j_owner = lay.owner_of_tile(tj);
            let k0j = lay.tile_start(tj);
            let tkj = lay.tile_cols(tj);
            let j_loc = lay.tile_local_offset(tj);
            let kmax = k0i.max(k0j);
            let height = n - kmax;

            // A-side: panel rows kmax.. (local copy on j's owner).
            let a_blk = if j_owner == i_owner {
                pi.submatrix(kmax - k0i, 0, height, tki)
            } else {
                let ptr = scratch[j_owner].expect("panel scratch");
                let mut full = vec![S::zero(); panel_elems];
                ctx.node.read_slice(ptr, 0, &mut full)?;
                Matrix::from_vec(pi_rows, tki, full).submatrix(kmax - k0i, 0, height, tki)
            };
            // B-side: X rows kmax.. of tile tj (still unoverwritten).
            let b_blk = x.read_block(j_owner, kmax, height, j_loc, tkj)?;
            let mut c = Matrix::<S>::zeros(tki, tkj);
            ctx.kernels.gemm_hn(&mut c, &a_blk, &b_blk, S::one())?;
            ctx.charge_gemm(j_owner, tki, tkj, height)?;
            // Write result rows k0i..k1i of tile tj.
            x.write_block(j_owner, k0i, j_loc, &c)?;
        }

        ctx.node.free(src_scratch)?;
        for s in scratch.into_iter().flatten() {
            ctx.node.free(s)?;
        }
    }

    // Copy the inverse into `a`'s panels (local device copies).
    for d in 0..ndev {
        let lc = lay_local_cols(&lay, d);
        if lc == 0 {
            continue;
        }
        ctx.panel_copy(x.panels()[d], a.panels()[d], n * lc * esize, ctx.device_ready(d))?;
    }
    x.free()?;
    let _ = ctx.end_phase();
    Ok(())
}

fn lay_local_cols(lay: &crate::layout::BlockCyclic1D, d: usize) -> usize {
    use crate::layout::ColumnLayout;
    lay.local_cols(d)
}

/// Grid-native inverse over a `P × Q` factor: numerics are the exact
/// 1D kernel sequence computed from a host mirror (bitwise identical
/// results). The schedule un-binds both phases from single owners:
/// phase 1's trtri column pipelines split each tail update across the
/// `P` row owners of the current tile's grid column (solved blocks
/// ride column rings to them, the running tail hands off along grid
/// rows); phase 2's lauum rounds broadcast the panel as `P` parallel
/// **row-ring** segments of `≈ rows/P` (instead of one devices-wide
/// `O(rows·T)` broadcast) and reduce each result block's partial
/// products up its column ring.
fn potri_dist_grid<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    grid: BlockCyclic2D,
) -> Result<()> {
    let n = a.rows();
    if grid.tile_r() != grid.tile_c() {
        return Err(Error::layout(
            "grid-native potri needs square tiles (tile_r == tile_c) — redistribute first",
        ));
    }
    let (p, q) = grid.grid();
    let comm = GridComm::new(p, q);
    let rd = grid.row_dim();
    let cd = grid.col_dim();
    let nt = cd.num_tiles();
    let esize = std::mem::size_of::<S>();
    ctx.node.metrics().note_grid_solve(p as u64, q as u64);

    ctx.begin_phase();
    let amir = a.mirror_host()?;
    // The device-side X workspace (the paper's §3 memory cost) is
    // allocated for real so capacity accounting matches the 1D path;
    // numerics evolve on its host mirror below.
    let x_dev = DistMatrix::<S>::alloc(ctx.node, n, *a.layout())?;
    let mut x = Matrix::<S>::zeros(n, n);

    // ---- Phase 1: X = L⁻¹, one row-split pipeline per column tile.
    for t in 0..nt {
        let tk = cd.tile_len(t);
        let k0 = cd.tile_start(t);

        let mut tail = Matrix::<S>::zeros(n - k0, tk);
        for c in 0..tk {
            tail[(c, c)] = S::one();
        }

        for j in t..nt {
            let tj = cd.tile_len(j);
            let j0 = cd.tile_start(j);
            let j1 = j0 + tj;
            let rj = rd.owner(j);
            let cj = cd.owner(j);
            let djj = comm.device(rj, cj);

            // Solve the diagonal block on tile (j, j)'s owner.
            let ljj = amir.submatrix(j0, j0, tj, tj);
            let bj = tail.submatrix(j0 - k0, 0, tj, tk);
            let zj = ctx.kernels.trsm_llnn(&ljj, &bj)?;
            ctx.charge_panel(djj, GpuCostModel::flops_trsm(S::DTYPE, tj, tk, tj))?;

            // Store the solved block at X tile (j, t) — a hop along
            // grid row rj when the columns differ.
            x.set_submatrix(j0, k0, &zj);
            let x_owner = comm.device(rj, cd.owner(t));
            ctx.charge_ring_p2p(RingAxis::Row, djj, x_owner, tj * tk * esize)?;

            // Update the running tail below, split across grid rows.
            let below = n - j1;
            if below > 0 {
                let mut segb = vec![0usize; p];
                for jj in (j + 1)..nt {
                    segb[rd.owner(jj)] += rd.tile_len(jj);
                }
                let members: Vec<usize> = (0..p)
                    .filter(|&r| r != rj && segb[r] > 0)
                    .map(|r| comm.device(r, cj))
                    .collect();
                ctx.charge_col_ring_broadcast(djj, &members, tj * tk * esize)?;
                let panel = amir.submatrix(j1, j0, below, tj);
                let mut lower = tail.submatrix(j1 - k0, 0, below, tk);
                ctx.kernels.gemm_nn(&mut lower, &panel, &zj, -S::one())?;
                for r in 0..p {
                    if segb[r] > 0 {
                        ctx.charge_gemm(comm.device(r, cj), segb[r], tk, tj)?;
                    }
                }
                tail.set_submatrix(j1 - k0, 0, &lower);
                let cnext = cd.owner(j + 1);
                if cnext != cj {
                    for r in 0..p {
                        if segb[r] > 0 {
                            ctx.charge_ring_p2p(
                                RingAxis::Row,
                                comm.device(r, cj),
                                comm.device(r, cnext),
                                segb[r] * tk * esize,
                            )?;
                        }
                    }
                }
            }
        }
    }

    // ---- Phase 2: A⁻¹ = Xᴴ·X in place over the mirror.
    for ti in 0..nt {
        let tki = cd.tile_len(ti);
        let k0i = cd.tile_start(ti);
        let pi_rows = n - k0i;
        let ri = rd.owner(ti);
        let ci = cd.owner(ti);

        // Snapshot the panel BEFORE any round-ti writes (the in-place
        // correctness argument of the 1D path), then broadcast it as P
        // parallel row-ring segments.
        let pi = x.submatrix(k0i, k0i, pi_rows, tki);
        let mut segi = vec![0usize; p];
        for j in ti..nt {
            segi[rd.owner(j)] += rd.tile_len(j);
        }
        for r in 0..p {
            if segi[r] == 0 {
                continue;
            }
            let members: Vec<usize> =
                (0..q).filter(|&c| c != ci).map(|c| comm.device(r, c)).collect();
            ctx.charge_row_ring_broadcast(comm.device(r, ci), &members, segi[r] * tki * esize)?;
        }

        for tj in 0..nt {
            let tkj = cd.tile_len(tj);
            let k0j = cd.tile_start(tj);
            let cj = cd.owner(tj);
            let kmax = k0i.max(k0j);
            let height = n - kmax;
            let tmax = ti.max(tj);

            let a_blk = pi.submatrix(kmax - k0i, 0, height, tki);
            let b_blk = x.submatrix(kmax, k0j, height, tkj);
            let mut c = Matrix::<S>::zeros(tki, tkj);
            ctx.kernels.gemm_hn(&mut c, &a_blk, &b_blk, S::one())?;
            // Partial products on the grid rows holding the
            // contraction, reduced up column cj to the result block's
            // owner (tile (ti, tj)).
            let mut segm = vec![0usize; p];
            for jj in tmax..nt {
                segm[rd.owner(jj)] += rd.tile_len(jj);
            }
            for r in 0..p {
                if segm[r] > 0 {
                    ctx.charge_gemm(comm.device(r, cj), tki, tkj, segm[r])?;
                }
            }
            for r in 0..p {
                if r != ri && segm[r] > 0 {
                    ctx.charge_ring_p2p(
                        RingAxis::Col,
                        comm.device(r, cj),
                        comm.device(ri, cj),
                        tki * tkj * esize,
                    )?;
                }
            }
            x.set_submatrix(k0i, k0j, &c);
        }
    }

    // Copy the inverse into `a` (local device copies, charged at the
    // link model's local bandwidth).
    for d in 0..ctx.node.num_devices() {
        let bytes = grid.local_elems(d) * esize;
        if bytes == 0 {
            continue;
        }
        ctx.charge_device_time(d, ctx.node.topology().copy_time(d, d, bytes), 0)?;
    }
    a.write_back_host(&x)?;
    x_dev.free()?;
    let _ = ctx.end_phase();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::GpuCostModel;
    use crate::device::SimNode;
    use crate::layout::BlockCyclic1D;
    use crate::linalg::{tol_for, FrobNorm};
    use crate::scalar::{c64, Scalar};
    use crate::solver::{potrf_dist, SolverBackend};
    use crate::tile::Layout1D;

    fn run_potri<S: Scalar>(n: usize, tile: usize, ndev: usize, seed: u64) {
        let node = SimNode::new_uniform(ndev, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<S>::Native;
        let ctx = Ctx::new(&node, &model, &backend);

        let a = Matrix::<S>::spd_random(n, seed);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        potri_dist(&ctx, &mut dm).unwrap();
        let inv = dm.gather().unwrap();

        let ident = a.matmul(&inv);
        assert!(
            ident.rel_err(&Matrix::eye(n)) < tol_for::<S>(n) * 10.0,
            "A·A⁻¹ != I (n={n} T={tile} d={ndev} {:?}): {}",
            S::DTYPE,
            ident.rel_err(&Matrix::eye(n))
        );
        // Result must be Hermitian (full storage).
        assert!(inv.rel_err(&inv.adjoint()) < tol_for::<S>(n) * 10.0);
    }

    #[test]
    fn potri_f64() {
        run_potri::<f64>(24, 4, 4, 1);
    }

    #[test]
    fn potri_f64_ragged() {
        run_potri::<f64>(27, 5, 3, 2);
    }

    #[test]
    fn potri_c128_paper_case() {
        // Fig. 3b benchmarks potri on complex128.
        run_potri::<c64>(20, 4, 4, 3);
    }

    #[test]
    fn potri_f32() {
        run_potri::<f32>(16, 4, 2, 4);
    }

    #[test]
    fn potri_single_device() {
        run_potri::<f64>(12, 3, 1, 5);
    }

    #[test]
    fn potri_diag_is_reciprocal() {
        // diag(1..N)⁻¹ = diag(1, 1/2, ..., 1/N) — the paper's matrix.
        let n = 12;
        let node = SimNode::new_uniform(2, 1 << 24);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::spd_diag(n);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, 3, 2).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        potri_dist(&ctx, &mut dm).unwrap();
        let inv = dm.gather().unwrap();
        for i in 0..n {
            assert!((inv[(i, i)] - 1.0 / (i + 1) as f64).abs() < 1e-12);
        }
    }

    #[test]
    fn potri_pipelined_matches_barrier_and_shrinks_timeline() {
        use crate::solver::PipelineConfig;
        let run = |cfg: PipelineConfig| -> (Matrix<f64>, f64) {
            let node = SimNode::new_uniform(4, 1 << 26);
            let model = GpuCostModel::h200();
            let backend = SolverBackend::<f64>::Native;
            let a = Matrix::<f64>::spd_random(32, 23);
            let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(32, 4, 4).unwrap());
            let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
            node.reset_accounting();
            let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
            potrf_dist(&ctx, &mut dm).unwrap();
            potri_dist(&ctx, &mut dm).unwrap();
            (dm.gather().unwrap(), node.sim_time())
        };
        let (inv_barrier, t_barrier) = run(PipelineConfig::barrier());
        let (inv_look, t_look) = run(PipelineConfig::lookahead(2));
        assert_eq!(inv_barrier.as_slice(), inv_look.as_slice(), "schedule changed numerics");
        assert!(t_look < t_barrier, "pipelined potri {t_look} !< barrier {t_barrier}");
    }

    #[test]
    fn potri_no_leaked_workspace() {
        let node = SimNode::new_uniform(2, 1 << 24);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::spd_random(16, 6);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(16, 4, 2).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        potri_dist(&ctx, &mut dm).unwrap();
        for rep in node.memory_reports() {
            assert_eq!(rep.allocations, 1, "workspace must be freed");
        }
        // Peak usage must reflect the X workspace (≈2× the panel).
        assert!(node.memory_reports()[0].peak_used >= 2 * node.memory_reports()[0].used);
    }
}
