//! Distributed right-looking blocked Cholesky over the 1D block-cyclic
//! layout (the `cusolverMgPotrf` analogue), with k-step panel lookahead.
//!
//! Per column tile `t` (owned entirely by one device in a 1D layout):
//!
//! 1. `potf2` the diagonal block `A[t,t]` on the owner;
//! 2. `trsm` the sub-diagonal panel `L[t+1.., t] = A[t+1.., t]·L_tt⁻ᴴ`
//!    on the owner;
//! 3. broadcast the panel to every device owning a later tile
//!    (peer-to-peer copies of a packed panel buffer — cuSOLVERMg's
//!    workspace broadcast);
//! 4. every device updates its own later tiles:
//!    `A[j.., j] −= P_j · P̂_jᴴ` (SYRK-shaped GEMM, perfectly parallel
//!    across devices — this is where the cyclic layout's load balance
//!    pays off).
//!
//! ## Lookahead schedule
//!
//! With a pipelined [`Ctx`] (see [`super::PipelineConfig`]), the
//! *timing* of those operations is issued onto per-device streams with
//! event dependencies instead of the strict per-device clock:
//!
//! * panel ops (1–2) run on the owner's **priority panel stream**,
//!   gated only on the moment tile column `t` absorbed step `t−1`'s
//!   update — so panel `t+1` factors while the owner's remaining
//!   step-`t` trailing GEMMs are still on its compute stream (the
//!   classic lookahead overlap), bounded to `lookahead` steps ahead of
//!   the trailing-update frontier;
//! * broadcasts (3) ride the owner's **copy stream**, gated on the
//!   panel completion, freeing the compute timeline;
//! * each trailing GEMM (4) is gated on `max(panel arrival on its
//!   device, previous update of its own column)` on the owner's
//!   **compute stream**.
//!
//! Numerics are identical under both schedules (the host executes the
//! same kernels in the same order); only the simulated timeline — and
//! therefore the projected makespan — changes.
//!
//! ## Grid-native execution (`P > 1`)
//!
//! On a [`BlockCyclic2D`] grid with square tiles the same factorization
//! executes **2D-parallel** ([`potrf_dist_grid`]): the diagonal block
//! factors on its owner, `L_tt` rides a **column ring** to the `P` row
//! owners of the panel, each of which `trsm`s only its own `below/P`
//! rows; the solved row segments ride **row rings** sideways and the
//! transposed blocks ride column rings down, so per-step broadcast
//! volume is `O(below·T/P)` per disjoint ring instead of `O(below·T)`
//! devices-wide; and every device's trailing update is **one fused
//! local GEMM** over its `local_rows × local_cols` trailing block (the
//! ScaLAPACK shape — one launch per device per step). The k-step panel
//! lookahead is preserved: the panel frontier is gated per tile column
//! exactly as in 1D, and lookahead strictly beats barrier on grids
//! (pinned in `tests/golden/potrf2d_timelines.txt`). Numerics are
//! **bitwise identical** to the 1D path — the host executes the exact
//! same kernel sequence (full-panel `trsm`, per-tile-column trailing
//! GEMMs); only ownership, and therefore the timeline, changes.

use super::{Ctx, GridComm, RingAxis};
use crate::costmodel::GpuCostModel;
use crate::error::{Error, Result};
use crate::layout::{BlockCyclic2D, MatrixLayout};
use crate::linalg::Matrix;
use crate::scalar::Scalar;
use crate::tile::DistMatrix;

/// Factor a Hermitian positive-definite `DistMatrix` (block-cyclic
/// layout) in place into its lower Cholesky factor. Dispatches on the
/// handle: 1D column layouts (and `P = 1` grids of full-height tiles,
/// whose storage is bitwise columnar) run the columnar path; `P × Q`
/// grids with square tiles run grid-native.
pub fn potrf_dist<S: Scalar>(ctx: &Ctx<'_, S>, a: &mut DistMatrix<S>) -> Result<()> {
    if a.layout().compat_1d(a.rows()).is_none() {
        if let Some(grid) = a.layout().grid2d().copied() {
            return potrf_dist_grid(ctx, a, grid);
        }
    }
    // Compatibility path: a 1D block-cyclic handle, or a P=1 grid whose
    // storage is bitwise columnar (see `LayoutKind::compat_1d`).
    let lay = a
        .layout()
        .compat_1d(a.rows())
        .ok_or_else(|| Error::layout("potrf requires a block-cyclic column layout — redistribute first"))?;
    let n = a.rows();
    if n != a.cols() {
        return Err(Error::shape(format!("potrf needs square matrix, got {}x{}", n, a.cols())));
    }
    let ntiles = lay.num_tiles();
    let ndev = ctx.node.num_devices();

    ctx.begin_phase();
    let tl = ctx.timeline();
    let lookahead = ctx.pipeline.lookahead;
    // Pipelined charge: issue `secs` of work on `stream` (owned by
    // `dev`) no earlier than `not_before`; returns the completion time.
    // One bookkeeping site for all three kernel classes below.
    let issue = |stream: &crate::device::Stream, dev: usize, not_before: f64, secs: f64, flops: u64| -> f64 {
        let done = stream.issue_after(not_before, secs);
        if let Some(tl) = tl {
            tl.note_busy(dev, secs);
        }
        ctx.node.metrics().add_kernel(flops);
        done
    };
    // Pipelined timing state, in simulated seconds:
    //   col_updated[j]       — completion of the latest update applied to
    //                          tile column j (gates its panel factorization);
    //   step_updates_done[t] — completion of the last trailing update of
    //                          step t (bounds the lookahead depth).
    let mut col_updated = vec![0.0f64; ntiles];
    let mut step_updates_done = vec![0.0f64; ntiles];

    for t in 0..ntiles {
        // Panel boundary: a queued latency-sensitive solve may run here.
        ctx.preempt_point();
        let owner = lay.owner_of_tile(t);
        let k0 = lay.tile_start(t);
        let tk = lay.tile_cols(t);
        let loc0 = lay.tile_local_offset(t);
        let k1 = k0 + tk;

        // 1. Diagonal block factorization on the owner.
        let diag = a.read_block(owner, k0, tk, loc0, tk)?;
        let lkk = ctx.kernels.potf2(&diag).map_err(|e| match e {
            // Re-base the failing minor to the global index, as
            // cusolverMg reports a global `info`.
            Error::NotPositiveDefinite { minor } => Error::NotPositiveDefinite { minor: k0 + minor },
            other => other,
        })?;
        let potf2_flops = GpuCostModel::flops_potf2(S::DTYPE, tk);
        let mut panel_done = 0.0f64;
        if let Some(tl) = tl {
            // Lookahead gate: the column must have absorbed every prior
            // update, and the panel frontier may run at most `lookahead`
            // steps ahead of the trailing-update frontier.
            let mut nb = col_updated[t];
            if t > lookahead {
                nb = nb.max(step_updates_done[t - 1 - lookahead]);
            }
            let secs = ctx.model.panel_time(S::DTYPE, potf2_flops);
            panel_done = issue(tl.panel(owner), owner, nb, secs, potf2_flops);
        } else {
            ctx.charge_panel(owner, potf2_flops)?;
        }
        a.write_block(owner, k0, loc0, &lkk)?;
        // Canonical lower factor: zero this tile column above the diagonal.
        if k0 > 0 {
            a.write_block(owner, 0, loc0, &Matrix::<S>::zeros(k0, tk))?;
        }

        let below = n - k1;
        if below == 0 {
            continue;
        }

        // 2. Panel solve on the owner (same priority stream).
        let b = a.read_block(owner, k1, below, loc0, tk)?;
        let panel = ctx.kernels.trsm_rlhc(&b, &lkk)?;
        let trsm_flops = GpuCostModel::flops_trsm(S::DTYPE, below, tk, tk);
        if let Some(tl) = tl {
            let secs = ctx.model.panel_time(S::DTYPE, trsm_flops);
            panel_done = issue(tl.panel(owner), owner, 0.0, secs, trsm_flops);
        } else {
            ctx.charge_panel(owner, trsm_flops)?;
        }
        a.write_block(owner, k1, loc0, &panel)?;

        if t + 1 == ntiles {
            continue;
        }

        // 3. Broadcast the packed panel to devices owning later tiles.
        // Pack on the owner (contiguous below×tk scratch), then one peer
        // copy per receiving device — the cuSOLVERMg workspace pattern.
        // Pipelined: copies ride the owner's copy stream, gated on the
        // panel completion; `recv_time[d]` is when device d can read it.
        let panel_elems = below * tk;
        let panel_bytes = panel_elems * std::mem::size_of::<S>();
        let mut needs_panel = vec![false; ndev];
        for j in (t + 1)..ntiles {
            needs_panel[lay.owner_of_tile(j)] = true;
        }
        let src_scratch = ctx.node.alloc_scalars::<S>(owner, panel_elems)?;
        ctx.node.write_slice(src_scratch, 0, panel.as_slice())?;
        let mut scratch = vec![None; ndev];
        let mut recv_time = vec![0.0f64; ndev];
        for d in 0..ndev {
            if !needs_panel[d] || d == owner {
                continue;
            }
            let dst = ctx.node.alloc_scalars::<S>(d, panel_elems)?;
            recv_time[d] = ctx.panel_copy(src_scratch, dst, panel_bytes, panel_done)?;
            scratch[d] = Some(dst);
        }

        // 4. Trailing updates: every later tile j on its own device.
        let mut step_max = 0.0f64;
        for j in (t + 1)..ntiles {
            let d = lay.owner_of_tile(j);
            let j0 = lay.tile_start(j);
            let tj = lay.tile_cols(j);
            let locj = lay.tile_local_offset(j);
            // Panel rows for this tile: P_j = panel[j0-k1 ..], P̂_j = panel[j0-k1 .. j0-k1+tj].
            let pr0 = j0 - k1;
            let height = n - j0;
            let (pj, pj_hat) = if d == owner {
                (panel.submatrix(pr0, 0, height, tk), panel.submatrix(pr0, 0, tj, tk))
            } else {
                // Read from the received scratch copy (device-resident).
                let ptr = scratch[d].expect("panel scratch must exist");
                let mut full = vec![S::zero(); panel_elems];
                ctx.node.read_slice(ptr, 0, &mut full)?;
                let pm = Matrix::from_vec(below, tk, full);
                (pm.submatrix(pr0, 0, height, tk), pm.submatrix(pr0, 0, tj, tk))
            };
            let mut c = a.read_block(d, j0, height, locj, tj)?;
            ctx.kernels.gemm_nh(&mut c, &pj, &pj_hat, -S::one())?;
            if let Some(tl) = tl {
                let dep0 = if d == owner { panel_done } else { recv_time[d] };
                let dep = dep0.max(col_updated[j]);
                let secs = ctx.model.gemm_time(S::DTYPE, height, tj, tk);
                let fl = GpuCostModel::flops_gemm(S::DTYPE, height, tj, tk);
                let done = issue(tl.compute(d), d, dep, secs, fl);
                col_updated[j] = done;
                if done > step_max {
                    step_max = done;
                }
            } else {
                ctx.charge_gemm(d, height, tj, tk)?;
            }
            a.write_block(d, j0, locj, &c)?;
        }
        step_updates_done[t] = step_max;

        // Release broadcast scratch.
        ctx.node.free(src_scratch)?;
        for s in scratch.into_iter().flatten() {
            ctx.node.free(s)?;
        }
    }
    let _ = ctx.end_phase();
    Ok(())
}

/// The grid-native factorization (see the module docs): identical
/// numerics to the 1D path computed on a host mirror, with the
/// schedule — panel ops, ring collectives, fused local trailing
/// updates — charged onto the `P × Q` device grid under both the
/// barrier and lookahead disciplines.
fn potrf_dist_grid<S: Scalar>(
    ctx: &Ctx<'_, S>,
    a: &mut DistMatrix<S>,
    grid: BlockCyclic2D,
) -> Result<()> {
    let n = a.rows();
    if n != a.cols() {
        return Err(Error::shape(format!("potrf needs square matrix, got {}x{}", n, a.cols())));
    }
    if grid.tile_r() != grid.tile_c() {
        return Err(Error::layout(
            "grid-native potrf needs square tiles (tile_r == tile_c) — redistribute first",
        ));
    }
    let (p, q) = grid.grid();
    let comm = GridComm::new(p, q);
    let rd = grid.row_dim();
    let cd = grid.col_dim();
    let nt = cd.num_tiles();
    let ndev = ctx.node.num_devices();
    let esize = std::mem::size_of::<S>();
    ctx.node.metrics().note_grid_solve(p as u64, q as u64);

    ctx.begin_phase();
    let tl = ctx.timeline();
    let lookahead = ctx.pipeline.lookahead;
    // Pipelined charge helper, identical to the 1D path's.
    let issue = |stream: &crate::device::Stream, dev: usize, not_before: f64, secs: f64, flops: u64| -> f64 {
        let done = stream.issue_after(not_before, secs);
        if let Some(tl) = tl {
            tl.note_busy(dev, secs);
        }
        ctx.node.metrics().add_kernel(flops);
        done
    };

    // Numerics evolve on a host mirror (read once, written back once;
    // every kernel/copy is charged explicitly below — the same
    // discipline as `syevd_dist_grid`).
    let mut host = a.mirror_host()?;

    // Pipelined gating state, in simulated seconds:
    //   colgate[k]   — completion of the latest trailing update applied
    //                  to tile column k (gates its panel factorization);
    //   step_done[t] — completion of step t's trailing updates (bounds
    //                  the lookahead depth).
    let mut colgate = vec![0.0f64; nt];
    let mut step_done = vec![0.0f64; nt];

    for t in 0..nt {
        // Panel boundary: a queued latency-sensitive solve may run here.
        ctx.preempt_point();
        let tk = cd.tile_len(t);
        let k0 = cd.tile_start(t);
        let k1 = k0 + tk;
        let rt = rd.owner(t);
        let ct = cd.owner(t);
        let diag = comm.device(rt, ct);

        // 1. Diagonal block factorization on tile (t, t)'s owner.
        let dblk = host.submatrix(k0, k0, tk, tk);
        let lkk = ctx.kernels.potf2(&dblk).map_err(|e| match e {
            Error::NotPositiveDefinite { minor } => Error::NotPositiveDefinite { minor: k0 + minor },
            other => other,
        })?;
        let potf2_flops = GpuCostModel::flops_potf2(S::DTYPE, tk);
        // Panel-frontier gate: the tile column must have absorbed every
        // prior update, and the frontier may run at most `lookahead`
        // steps ahead of the trailing-update frontier.
        let mut nb = colgate[t];
        if t > lookahead {
            nb = nb.max(step_done[t - 1 - lookahead]);
        }
        let mut potf2_done = 0.0f64;
        if let Some(tl) = tl {
            let secs = ctx.model.panel_time(S::DTYPE, potf2_flops);
            potf2_done = issue(tl.panel(diag), diag, nb, secs, potf2_flops);
        } else {
            ctx.charge_panel(diag, potf2_flops)?;
        }
        host.set_submatrix(k0, k0, &lkk);
        // Canonical lower factor: zero this tile column above the diagonal.
        if k0 > 0 {
            host.set_submatrix(0, k0, &Matrix::<S>::zeros(k0, tk));
        }

        let below = n - k1;
        if below == 0 {
            continue;
        }

        // Trailing ownership extents: seg[r] = panel rows owned by grid
        // row r; cols_of[c] = trailing columns owned by grid column c.
        let mut seg = vec![0usize; p];
        for j in (t + 1)..nt {
            seg[rd.owner(j)] += rd.tile_len(j);
        }
        let mut cols_of = vec![0usize; q];
        for k in (t + 1)..nt {
            cols_of[cd.owner(k)] += cd.tile_len(k);
        }

        // 2. L_tt column ring: the factored diagonal block flows down
        // grid column ct to the panel's row owners (who trsm their own
        // row segments against it).
        let ltt_members: Vec<usize> =
            (0..p).filter(|&r| r != rt && seg[r] > 0).map(|r| comm.device(r, ct)).collect();
        let mut ltt_arrival = vec![0.0f64; ndev];
        let ltt_bytes = tk * tk * esize;
        if !ltt_members.is_empty() {
            if tl.is_some() {
                // The pipelined arm needs per-member arrival times (the
                // trsm gates on them) — the fabric-aware ring helper
                // returns delivery pairs, gated on the potf2.
                for (m, done) in ctx.pipelined_ring_arrivals(
                    RingAxis::Col, diag, &ltt_members, ltt_bytes, potf2_done, 1,
                )? {
                    ltt_arrival[m] = done;
                }
            } else {
                ctx.charge_col_ring_broadcast(diag, &ltt_members, ltt_bytes)?;
            }
        }

        // 3. Panel solve, split across the P row owners: each trsm's
        // only its own seg[r] rows (the 2D win over the 1D path's one
        // whole-panel trsm on a single owner).
        let mut trsm_done = vec![0.0f64; p];
        for r in 0..p {
            if seg[r] == 0 {
                continue;
            }
            let src = comm.device(r, ct);
            let fl = GpuCostModel::flops_trsm(S::DTYPE, seg[r], tk, tk);
            if let Some(tl) = tl {
                let arrive = if src == diag { potf2_done } else { ltt_arrival[src] };
                let secs = ctx.model.panel_time(S::DTYPE, fl);
                trsm_done[r] = issue(tl.panel(src), src, nb.max(arrive), secs, fl);
            } else {
                ctx.charge_panel(src, fl)?;
            }
        }
        // Numerics: the exact 1D kernel call — one full-panel trsm.
        let b = host.submatrix(k1, k0, below, tk);
        let panel = ctx.kernels.trsm_rlhc(&b, &lkk)?;
        host.set_submatrix(k1, k0, &panel);

        // 4. Row rings: each row owner ships its solved row segment
        // sideways to the grid columns owning trailing tiles.
        let mut row_arrival = vec![0.0f64; ndev];
        for r in 0..p {
            if seg[r] == 0 {
                continue;
            }
            let src = comm.device(r, ct);
            let members: Vec<usize> =
                (0..q).filter(|&c| c != ct && cols_of[c] > 0).map(|c| comm.device(r, c)).collect();
            if members.is_empty() {
                continue;
            }
            let bytes = seg[r] * tk * esize;
            if tl.is_some() {
                for (m, done) in ctx.pipelined_ring_arrivals(
                    RingAxis::Row, src, &members, bytes, trsm_done[r], 1,
                )? {
                    row_arrival[m] = done;
                }
            } else {
                ctx.charge_row_ring_broadcast(src, &members, bytes)?;
            }
        }

        // 5. Column rings: the transposed panel blocks L[k,t]ᴴ flow
        // down each trailing grid column from the grid row that owns
        // them (locally for column ct, row-ring-delivered elsewhere).
        let mut colt_arrival = vec![0.0f64; ndev];
        for c in 0..q {
            if cols_of[c] == 0 {
                continue;
            }
            let mut blk = vec![0usize; p];
            for k in (t + 1)..nt {
                if cd.owner(k) == c {
                    blk[rd.owner(k)] += cd.tile_len(k);
                }
            }
            // Contention: every source row with a nonzero block
            // broadcasts down this column at once, so each receiver's
            // link carries `conc` concurrent transfers — the per-link
            // sharing term tall grids (large P) pay for and wide grids
            // do not (the PR 5 ladder's missing cost).
            let conc = blk.iter().filter(|&&b| b > 0).count();
            for rs in 0..p {
                if blk[rs] == 0 {
                    continue;
                }
                let src = comm.device(rs, c);
                let members: Vec<usize> =
                    (0..p).filter(|&r| r != rs && seg[r] > 0).map(|r| comm.device(r, c)).collect();
                if members.is_empty() {
                    continue;
                }
                let bytes = blk[rs] * tk * esize;
                if tl.is_some() {
                    let src_ready = if c == ct { trsm_done[rs] } else { row_arrival[src] };
                    for (m, done) in ctx.pipelined_ring_arrivals(
                        RingAxis::Col, src, &members, bytes, src_ready, conc,
                    )? {
                        colt_arrival[m] = colt_arrival[m].max(done);
                    }
                } else {
                    ctx.charge_ring_broadcast_contended(
                        RingAxis::Col, src, &members, bytes, conc,
                    )?;
                }
            }
        }

        // 6. Trailing updates. Numerics: the exact 1D per-tile-column
        // GEMM sequence. Charges: fused local GEMMs per device, split
        // **lookahead-first** — each device updates its piece of the
        // NEXT panel column (tile column t+1) as its own launch before
        // the rest of its local trailing block (the classic lookahead
        // split), so the next panel factors while the bulk update is
        // still in flight.
        for j in (t + 1)..nt {
            let j0 = cd.tile_start(j);
            let tj = cd.tile_len(j);
            let height = n - j0;
            let pr0 = j0 - k1;
            let pj = panel.submatrix(pr0, 0, height, tk);
            let pj_hat = panel.submatrix(pr0, 0, tj, tk);
            let mut cmat = host.submatrix(j0, j0, height, tj);
            ctx.kernels.gemm_nh(&mut cmat, &pj, &pj_hat, -S::one())?;
            host.set_submatrix(j0, j0, &cmat);
        }
        let mut fl_next = vec![0u64; ndev];
        let mut fl_rest = vec![0u64; ndev];
        for j in (t + 1)..nt {
            let r = rd.owner(j);
            for k in (t + 1)..=j {
                let c = cd.owner(k);
                let f = GpuCostModel::flops_gemm(S::DTYPE, rd.tile_len(j), cd.tile_len(k), tk);
                if k == t + 1 {
                    fl_next[comm.device(r, c)] += f;
                } else {
                    fl_rest[comm.device(r, c)] += f;
                }
            }
        }
        let next_w = cd.tile_len(t + 1);
        let cnext = cd.owner(t + 1);
        let mut step_max = 0.0f64;
        for r in 0..p {
            for c in 0..q {
                let d = comm.device(r, c);
                if fl_next[d] == 0 && fl_rest[d] == 0 {
                    continue;
                }
                let dep = if tl.is_some() {
                    let panel_arr = if c == ct { trsm_done[r] } else { row_arrival[d] };
                    panel_arr.max(colt_arrival[d])
                } else {
                    0.0
                };
                if fl_next[d] > 0 {
                    let util = GpuCostModel::gemm_utilization(tk.min(seg[r]).min(next_w));
                    let secs = ctx.model.launch_overhead
                        + fl_next[d] as f64 / (ctx.model.rate(S::DTYPE) * util);
                    if let Some(tl) = tl {
                        let done = issue(tl.compute(d), d, dep, secs, fl_next[d]);
                        if done > step_max {
                            step_max = done;
                        }
                        if done > colgate[t + 1] {
                            colgate[t + 1] = done;
                        }
                    } else {
                        ctx.charge_device_time(d, secs, fl_next[d])?;
                    }
                }
                if fl_rest[d] > 0 {
                    let rest_w = cols_of[c] - if c == cnext { next_w } else { 0 };
                    let util = GpuCostModel::gemm_utilization(tk.min(seg[r]).min(rest_w));
                    let secs = ctx.model.launch_overhead
                        + fl_rest[d] as f64 / (ctx.model.rate(S::DTYPE) * util);
                    if let Some(tl) = tl {
                        let done = issue(tl.compute(d), d, dep, secs, fl_rest[d]);
                        if done > step_max {
                            step_max = done;
                        }
                        for k in (t + 2)..nt {
                            if cd.owner(k) != c {
                                continue;
                            }
                            let touches = (k..nt).any(|j| rd.owner(j) == r);
                            if touches && done > colgate[k] {
                                colgate[k] = done;
                            }
                        }
                    } else {
                        ctx.charge_device_time(d, secs, fl_rest[d])?;
                    }
                }
            }
        }
        step_done[t] = step_max;
    }

    a.write_back_host(&host)?;
    let _ = ctx.end_phase();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::GpuCostModel;
    use crate::device::SimNode;
    use crate::layout::BlockCyclic1D;
    use crate::linalg::{self, tol_for, FrobNorm};
    use crate::scalar::{c32, c64};
    use crate::solver::{PipelineConfig, SolverBackend};
    use crate::tile::Layout1D;

    fn run_potrf<S: Scalar>(n: usize, tile: usize, ndev: usize, seed: u64) {
        let node = SimNode::new_uniform(ndev, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<S>::Native;
        let ctx = Ctx::new(&node, &model, &backend);

        let a = Matrix::<S>::spd_random(n, seed);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        let l = dm.gather().unwrap();

        // Compare against the host reference.
        let l_ref = linalg::potrf(&a).unwrap();
        assert!(
            l.rel_err(&l_ref) < tol_for::<S>(n),
            "distributed != reference potrf (n={n} T={tile} d={ndev} {:?}): {}",
            S::DTYPE,
            l.rel_err(&l_ref)
        );
        // And reconstruct.
        assert!(l.matmul(&l.adjoint()).rel_err(&a) < tol_for::<S>(n));
    }

    #[test]
    fn potrf_f64_even_tiles() {
        run_potrf::<f64>(32, 4, 4, 1);
    }

    #[test]
    fn potrf_f64_ragged() {
        run_potrf::<f64>(37, 5, 3, 2); // ragged edge tile, odd device count
    }

    #[test]
    fn potrf_f32() {
        run_potrf::<f32>(24, 4, 2, 3);
    }

    #[test]
    fn potrf_c64() {
        run_potrf::<c32>(20, 3, 4, 4);
    }

    #[test]
    fn potrf_c128() {
        run_potrf::<c64>(30, 4, 4, 5);
    }

    #[test]
    fn potrf_single_tile() {
        run_potrf::<f64>(8, 8, 2, 6); // whole matrix in one tile on dev 0
    }

    #[test]
    fn potrf_single_device() {
        run_potrf::<f64>(16, 4, 1, 7);
    }

    #[test]
    fn potrf_tile_one(){
        run_potrf::<f64>(12, 1, 3, 8); // column-cyclic extreme
    }

    #[test]
    fn potrf_rejects_contiguous_layout() {
        let node = SimNode::new_uniform(2, 1 << 20);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::spd_random(8, 1);
        let lay = Layout1D::Contiguous(crate::layout::ContiguousBlock::new(8, 2).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        assert!(matches!(potrf_dist(&ctx, &mut dm), Err(Error::Layout(_))));
    }

    #[test]
    fn potrf_reports_global_minor() {
        let node = SimNode::new_uniform(2, 1 << 20);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let mut a = Matrix::<f64>::spd_random(12, 2);
        a[(7, 7)] = -100.0; // break PD in tile 1 (T=4): global minor 8
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(12, 4, 2).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        match potrf_dist(&ctx, &mut dm) {
            Err(Error::NotPositiveDefinite { minor }) => assert_eq!(minor, 8),
            other => panic!("expected NotPositiveDefinite, got {other:?}"),
        }
    }

    #[test]
    fn potrf_advances_device_clocks_in_parallel() {
        let node = SimNode::new_uniform(4, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::new(&node, &model, &backend);
        let a = Matrix::<f64>::spd_random(64, 9);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(64, 4, 4).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        node.reset_accounting();
        potrf_dist(&ctx, &mut dm).unwrap();
        // All devices must have done work (load balance of the cyclic layout).
        for d in 0..4 {
            assert!(node.device(d).unwrap().clock().now() > 0.0, "device {d} idle");
        }
        // Peer traffic happened (panel broadcasts).
        assert!(node.metrics().snapshot().peer_bytes > 0);
        // No leaked scratch: panels only.
        for rep in node.memory_reports() {
            assert_eq!(rep.allocations, 1);
        }
    }

    /// Run potrf under a given schedule, returning (factor, makespan).
    fn potrf_with_schedule(
        n: usize,
        tile: usize,
        ndev: usize,
        seed: u64,
        cfg: PipelineConfig,
    ) -> (Matrix<f64>, f64) {
        let node = SimNode::new_uniform(ndev, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let a = Matrix::<f64>::spd_random(n, seed);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        node.reset_accounting();
        let ctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
        potrf_dist(&ctx, &mut dm).unwrap();
        (dm.gather().unwrap(), node.sim_time())
    }

    #[test]
    fn pipelined_matches_barrier_bitwise() {
        // The schedule is a timing overlay; numerics must be identical.
        let (l_barrier, _) = potrf_with_schedule(48, 4, 4, 11, PipelineConfig::barrier());
        let (l_look, _) = potrf_with_schedule(48, 4, 4, 11, PipelineConfig::lookahead(2));
        assert_eq!(l_barrier.as_slice(), l_look.as_slice());
    }

    #[test]
    fn lookahead_beats_barrier_makespan() {
        let (_, barrier) = potrf_with_schedule(64, 4, 4, 12, PipelineConfig::barrier());
        let (_, look) = potrf_with_schedule(64, 4, 4, 12, PipelineConfig::lookahead(2));
        assert!(
            look < barrier,
            "lookahead makespan {look} must beat barrier {barrier}"
        );
    }

    #[test]
    fn pipelined_no_leaked_scratch() {
        let node = SimNode::new_uniform(4, 1 << 26);
        let model = GpuCostModel::h200();
        let backend = SolverBackend::<f64>::Native;
        let ctx = Ctx::pipelined(&node, &model, &backend);
        let a = Matrix::<f64>::spd_random(32, 13);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(32, 4, 4).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
        potrf_dist(&ctx, &mut dm).unwrap();
        for rep in node.memory_reports() {
            assert_eq!(rep.allocations, 1, "pipelined path leaked scratch");
        }
        // The phase published overlap accounting.
        let m = node.metrics().snapshot();
        assert!(m.overlap_span_ns > 0);
        assert!(m.overlap_efficiency() > 0.0);
    }
}
