//! The tile-kernel interface and its pure-Rust implementation.
//!
//! Every FLOP the distributed solvers execute flows through this trait,
//! which is exactly the seam where the real JAXMg hands work to
//! cuSOLVERMg's CUDA kernels — and where this reproduction hands work
//! to the AOT-compiled XLA executables (`crate::runtime::XlaKernels`)
//! authored by the Pallas/JAX layers.

use crate::error::Result;
use crate::linalg::{self, Matrix};
use crate::scalar::Scalar;

/// Tile-level compute kernels. All matrices are small host-staged tiles
/// (the simulator's stand-in for VMEM/SMEM-resident blocks).
pub trait TileKernels<S: Scalar>: Send + Sync {
    /// Unblocked Cholesky of a tile: returns lower `L` with `A = L·Lᴴ`.
    fn potf2(&self, a: &Matrix<S>) -> Result<Matrix<S>>;

    /// Right solve against the adjoint factor: `X = B · L⁻ᴴ`
    /// (the potrf panel update).
    fn trsm_rlhc(&self, b: &Matrix<S>, l: &Matrix<S>) -> Result<Matrix<S>>;

    /// Left lower solve: `X = L⁻¹ · B` (potrs forward step).
    fn trsm_llnn(&self, l: &Matrix<S>, b: &Matrix<S>) -> Result<Matrix<S>>;

    /// Left lower-adjoint solve: `X = L⁻ᴴ · B` (potrs backward step).
    fn trsm_llhn(&self, l: &Matrix<S>, b: &Matrix<S>) -> Result<Matrix<S>>;

    /// `C ← C + α·A·B` — the trailing-update workhorse.
    fn gemm_nn(&self, c: &mut Matrix<S>, a: &Matrix<S>, b: &Matrix<S>, alpha: S) -> Result<()>;

    /// `C ← C + α·A·Bᴴ` (SYRK-shaped trailing update).
    fn gemm_nh(&self, c: &mut Matrix<S>, a: &Matrix<S>, b: &Matrix<S>, alpha: S) -> Result<()>;

    /// `C ← C + α·Aᴴ·B` (LAUUM / backward-solve updates).
    fn gemm_hn(&self, c: &mut Matrix<S>, a: &Matrix<S>, b: &Matrix<S>, alpha: S) -> Result<()>;

    /// Unblocked Cholesky of many independent tiles — the seam where a
    /// real backend installs a true batched kernel (cuBLAS
    /// `potrfBatched` / a vmapped Pallas tile kernel). The default
    /// loops [`TileKernels::potf2`] per tile, which keeps the batched
    /// small-solve sweeps ([`crate::batch::sweep`]) bitwise-identical
    /// to solving each system individually; the *timing* fusion (one
    /// launch per device per bucket) is charged by the sweep itself.
    /// The first failing tile aborts the batch with its error.
    fn potf2_batch(&self, tiles: &[Matrix<S>]) -> Result<Vec<Matrix<S>>> {
        tiles.iter().map(|a| self.potf2(a)).collect()
    }

    /// Backend name for logs/benches.
    fn name(&self) -> &'static str;
}

/// Reference backend: straight `crate::linalg` calls.
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeKernels;

impl<S: Scalar> TileKernels<S> for NativeKernels {
    fn potf2(&self, a: &Matrix<S>) -> Result<Matrix<S>> {
        linalg::potrf(a)
    }

    fn trsm_rlhc(&self, b: &Matrix<S>, l: &Matrix<S>) -> Result<Matrix<S>> {
        Ok(linalg::trsm_right_lower_h(b, l))
    }

    fn trsm_llnn(&self, l: &Matrix<S>, b: &Matrix<S>) -> Result<Matrix<S>> {
        Ok(linalg::trsm_left_lower(l, b))
    }

    fn trsm_llhn(&self, l: &Matrix<S>, b: &Matrix<S>) -> Result<Matrix<S>> {
        Ok(linalg::trsm_left_lower_h(l, b))
    }

    fn gemm_nn(&self, c: &mut Matrix<S>, a: &Matrix<S>, b: &Matrix<S>, alpha: S) -> Result<()> {
        linalg::dense_gemm_acc(c, a, b, alpha);
        Ok(())
    }

    fn gemm_nh(&self, c: &mut Matrix<S>, a: &Matrix<S>, b: &Matrix<S>, alpha: S) -> Result<()> {
        // C += α·A·Bᴴ. Materialize Bᴴ once per call; tiles are small.
        let bh = b.adjoint();
        linalg::dense_gemm_acc(c, a, &bh, alpha);
        Ok(())
    }

    fn gemm_hn(&self, c: &mut Matrix<S>, a: &Matrix<S>, b: &Matrix<S>, alpha: S) -> Result<()> {
        linalg::dense_gemm_hn_acc(c, a, b, alpha);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::{tol_for, FrobNorm};
    use crate::scalar::c64;

    #[test]
    fn native_potf2_roundtrip() {
        let a = Matrix::<f64>::spd_random(16, 1);
        let k = NativeKernels;
        let l = TileKernels::<f64>::potf2(&k, &a).unwrap();
        assert!(l.matmul(&l.adjoint()).rel_err(&a) < tol_for::<f64>(16));
    }

    #[test]
    fn native_gemm_nh_matches_adjoint() {
        let k = NativeKernels;
        let a = Matrix::<c64>::random(6, 4, 2);
        let b = Matrix::<c64>::random(5, 4, 3);
        let mut c1 = Matrix::<c64>::zeros(6, 5);
        k.gemm_nh(&mut c1, &a, &b, c64::new(-1.0, 0.0)).unwrap();
        let c2 = a.matmul(&b.adjoint()).scale(c64::new(-1.0, 0.0));
        assert!(c1.rel_err(&c2) < 1e-13);
    }

    #[test]
    fn native_trsm_variants_consistent() {
        let k = NativeKernels;
        let a = Matrix::<c64>::spd_random(8, 4);
        let l = TileKernels::<c64>::potf2(&k, &a).unwrap();
        let x = Matrix::<c64>::random(8, 3, 5);

        let b1 = l.matmul(&x);
        assert!(k.trsm_llnn(&l, &b1).unwrap().rel_err(&x) < 1e-12);

        let b2 = l.adjoint().matmul(&x);
        assert!(k.trsm_llhn(&l, &b2).unwrap().rel_err(&x) < 1e-12);

        let y = Matrix::<c64>::random(3, 8, 6);
        let b3 = y.matmul(&l.adjoint());
        assert!(k.trsm_rlhc(&b3, &l).unwrap().rel_err(&y) < 1e-12);
    }
}
