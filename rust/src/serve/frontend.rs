//! The rank-0 frontend: request queue, failure-aware routing, and the
//! single-caller solve path.
//!
//! [`MpmdService`] owns the FIFO request queue. A dispatcher thread
//! admits the queue head against the **workers' own** per-device
//! accountants (all-or-rollback across the live set for distributed
//! solves, a single least-loaded worker for pinned pods), then hands
//! execution off:
//!
//! * **distributed solves** run on a router pool as the single caller —
//!   live workers stage their shards locally and export them, rank 0
//!   opens the foreign handles (charging the modeled `cudaIpc`
//!   round-trip, [`Predictor::mpmd_overhead`]'s exact terms), assembles
//!   the pointers into a [`DistMatrix`] view, and invokes
//!   `potrf/potrs/potri/syevd_dist`;
//! * **small solves** coalesce in a [`BatchPlanner`] exactly as in the
//!   SPMD service; a flushed bucket becomes one pod **pinned to one
//!   worker**, swept on that worker's thread.
//!
//! ## Failure-aware routing
//!
//! Worker death (panic or [`MpmdService::kill_worker`]) never loses a
//! request. Every dispatched work item is accounted in-flight until it
//! either publishes or re-enters the queue; the re-entry paths are:
//!
//! * a staging reply never arrives (dead worker's mailbox dropped the
//!   job) — the router sees the disconnect;
//! * the solve fails and some participant is no longer alive (its
//!   freed shards poisoned the solve) — re-queued with the dead
//!   devices excluded;
//! * a pod job lands on (or is draining from) a dead worker — it hands
//!   itself back for re-routing, excluding that device;
//! * a degraded pod rerun dies mid-loop — the unpublished tail
//!   re-enters as a fresh pod on the remaining devices.
//!
//! Retries shrink the live set monotonically (excluded devices
//! accumulate), so routing terminates: either a retry completes on the
//! remaining devices or the request fails with "no live workers".
//!
//! [`Predictor::mpmd_overhead`]: crate::costmodel::Predictor::mpmd_overhead
//! [`BatchPlanner`]: crate::batch::BatchPlanner

use super::worker::{spawn_worker, StagedAlloc, WorkerCtx, WorkerJob, WorkerLink};
use crate::batch::{
    run_bucket, size_class, BatchPlanner, BatchPolicy, BucketKey, FlushedBucket, SmallRoutine,
};
use crate::coordinator::{
    handle_pair, panic_message, publish_failure, publish_one, DistPlan, Footprint, GridPlanCache,
    JobQueue, ServiceHandle, Slot, SolveStats,
};
pub use crate::coordinator::DistRoutine;
use crate::costmodel::{GpuCostModel, Predictor};
use crate::device::{DevPtr, SimNode};
use crate::error::{Error, Result};
use crate::ipc::{AddressSpace, IpcHandle, IpcRegistry};
use crate::linalg::Matrix;
use crate::scalar::{DType, Scalar};
use crate::solver::{
    potrf_dist, potri_dist, potrs_dist, syevd_dist, Ctx, PipelineConfig, SolverBackend,
};
use crate::tile::{build_panel, DistMatrix, LayoutKind};
use std::any::Any;
use std::collections::{HashMap, VecDeque};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Configuration of the MPMD serving subsystem.
#[derive(Clone, Debug)]
pub struct MpmdConfig {
    /// `T_A` of the distributed solve layout; also anchors the default
    /// smallness cut (`small_dim = 4·tile`).
    pub tile: usize,
    /// Cost model for solve charges, the batched-vs-distributed
    /// dispatch decision, and the `cudaIpc` round-trip charge.
    pub model: GpuCostModel,
    /// Timing schedule of the distributed solves (barrier by default,
    /// so MPMD results are bitwise-comparable to the seed schedule).
    pub pipeline: PipelineConfig,
    /// Coalescing knobs of the small-solve path.
    pub policy: BatchPolicy,
    /// Router threads executing distributed solves as the single
    /// caller (bounds distributed solves in flight).
    pub routers: usize,
    /// Process-grid override for distributed solves: `None` lets the
    /// shared planner pick `P × Q` per request (over the **live**
    /// worker set — a shrunk set is re-planned); `Some((p, q))` pins
    /// it (p·q must equal the live worker count at dispatch).
    pub grid: Option<(usize, usize)>,
}

impl MpmdConfig {
    /// Defaults anchored at tile size `tile` (`small_dim = 4·tile`).
    pub fn with_tile(tile: usize) -> Self {
        let policy = BatchPolicy { small_dim: 4 * tile, ..BatchPolicy::default() };
        MpmdConfig {
            tile,
            model: GpuCostModel::h200(),
            pipeline: PipelineConfig::barrier(),
            policy,
            routers: 2,
            grid: None,
        }
    }
}

impl Default for MpmdConfig {
    fn default() -> Self {
        Self::with_tile(64)
    }
}

// `DistRoutine` lives in `coordinator::admit` (shared with the SPMD
// front's `SolveService::submit_dist`) and is re-exported above.

// ---------------------------------------------------------------------------
// Frontend shared state (queue + wake-ups)
// ---------------------------------------------------------------------------

struct FrontState {
    queue: VecDeque<QueuedWork>,
    in_flight: usize,
    shutdown: bool,
}

/// The rank-0 frontend state workers and routers wake each other
/// through: the FIFO request queue, the in-flight count, and the one
/// condvar behind every release/completion/death notification.
pub(crate) struct FrontShared {
    state: Mutex<FrontState>,
    cv: Condvar,
}

impl FrontShared {
    fn new() -> Self {
        FrontShared {
            state: Mutex::new(FrontState {
                queue: VecDeque::new(),
                in_flight: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Wake the dispatcher (capacity released, worker died, ...).
    pub(crate) fn notify(&self) {
        self.cv.notify_all();
    }

    /// One dispatched work item finished (published its outcome).
    pub(crate) fn complete(&self) {
        let mut st = self.state.lock().unwrap();
        st.in_flight -= 1;
        drop(st);
        self.cv.notify_all();
    }

    /// A dispatched work item failed on dead devices: exclude them and
    /// put it back at the queue head for re-routing.
    pub(crate) fn requeue(&self, mut work: QueuedWork, dead: &[usize]) {
        for &d in dead {
            if !work.excluded.contains(&d) {
                work.excluded.push(d);
            }
        }
        work.attempts += 1;
        let mut st = self.state.lock().unwrap();
        st.queue.push_front(work);
        st.in_flight -= 1;
        drop(st);
        self.cv.notify_all();
    }

    /// Enqueue new work; hands the work back when the service is
    /// already shut down (the caller fails its waiters).
    pub(crate) fn enqueue(&self, work: QueuedWork) -> std::result::Result<(), QueuedWork> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err(work);
        }
        st.queue.push_back(work);
        drop(st);
        self.cv.notify_all();
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Work items
// ---------------------------------------------------------------------------

/// How a distributed work item ended.
pub(crate) enum ExecResult {
    /// Outcome published to the waiters (success or terminal failure).
    Published,
    /// Worker death poisoned the attempt: re-queue excluding the dead.
    Requeue(Vec<usize>),
}

/// How a worker-executed pod ended.
pub(crate) enum PodOutcome {
    Published,
    WorkerDead,
}

/// A distributed solve routed by the frontend (type-erased over dtype).
pub(crate) trait DistWork: Send + Sync {
    /// Plan the solve over the current live set: grid shape (selector
    /// or [`MpmdConfig::grid`] override), layout, exact footprint.
    fn plan(&self, shared: &Shared, ndev: usize) -> Result<DistPlan>;
    fn execute(
        &self,
        shared: &Shared,
        live: &[usize],
        plan: &DistPlan,
        queue_wait: Duration,
    ) -> ExecResult;
    fn fail(&self, msg: String);
}

/// A coalesced pod pinned to one worker (type-erased over dtype).
pub(crate) trait PodWork: Send + Sync {
    /// Arena bytes the pod needs on its single target device.
    fn bytes(&self) -> usize;
    fn run(&self, ctx: &WorkerCtx, queue_wait: Duration) -> PodOutcome;
    fn fail(&self, msg: String);
}

pub(crate) enum WorkKind {
    Dist(Arc<dyn DistWork>),
    Pod(Arc<dyn PodWork>),
}

/// One queued request plus its routing state.
pub(crate) struct QueuedWork {
    kind: WorkKind,
    /// Devices excluded by prior failures (grows monotonically).
    excluded: Vec<usize>,
    /// Dispatch attempts so far (diagnostics in terminal failures).
    attempts: u32,
    enqueued: Instant,
}

impl QueuedWork {
    fn fresh(kind: WorkKind) -> Self {
        QueuedWork { kind, excluded: Vec::new(), attempts: 0, enqueued: Instant::now() }
    }
}

/// Fail every waiter of a work item that can no longer be routed.
fn fail_work(work: QueuedWork, msg: String) {
    match work.kind {
        WorkKind::Dist(req) => req.fail(msg),
        WorkKind::Pod(pod) => pod.fail(msg),
    }
}

// ---------------------------------------------------------------------------
// Shared service state
// ---------------------------------------------------------------------------

/// Everything the dispatcher, routers, and worker jobs share.
pub(crate) struct Shared {
    node: SimNode,
    registry: Arc<IpcRegistry>,
    cfg: MpmdConfig,
    workers: Vec<WorkerLink>,
    front: Arc<FrontShared>,
    /// Memoized grid-shape selections for the distributed planner
    /// (keyed per live-set size, so degraded-mode retries re-plan).
    plans: GridPlanCache,
    /// The frontend's (rank 0's) address space: worker 0 is a thread of
    /// this process, so its shard needs no IPC export.
    caller: AddressSpace,
}

impl Shared {
    fn live_workers(&self, excluded: &[usize]) -> Vec<usize> {
        (0..self.workers.len())
            .filter(|d| self.workers[*d].alive() && !excluded.contains(d))
            .collect()
    }

    fn sim_now_ns(&self) -> u64 {
        (self.node.sim_time() * 1e9).round() as u64
    }
}

// ---------------------------------------------------------------------------
// Distributed solve requests
// ---------------------------------------------------------------------------

enum DistSlot<S: Scalar> {
    Mat(Slot<Matrix<S>>),
    Eig(Slot<(Vec<S::Real>, Matrix<S>)>),
}

enum DistOut<S: Scalar> {
    Mat(Matrix<S>),
    Eig(Vec<S::Real>, Matrix<S>),
}

struct DistReq<S: Scalar> {
    routine: DistRoutine,
    a: Arc<Matrix<S>>,
    rhs: Option<Matrix<S>>,
    slot: DistSlot<S>,
}

impl<S: Scalar> DistReq<S> {
    fn publish_ok(&self, out: DistOut<S>, stats: SolveStats) {
        match (&self.slot, out) {
            (DistSlot::Mat(slot), DistOut::Mat(x)) => publish_one(slot, Ok((x, stats))),
            (DistSlot::Eig(slot), DistOut::Eig(vals, vecs)) => {
                publish_one(slot, Ok(((vals, vecs), stats)))
            }
            _ => unreachable!("routine determines the output shape"),
        }
    }
}

/// One worker's staged shard as reported back to rank 0.
struct StagedShard {
    ptr: DevPtr,
    handle: Option<IpcHandle>,
}

/// Worker-side shard staging: build the panel for `sub_idx` of the
/// layout, allocate + upload it on this worker's device (through the
/// possibly-degraded node view), and export it unless this *is* the
/// caller's process.
fn stage_shard<S: Scalar>(
    ctx: &WorkerCtx,
    sub: &SimNode,
    sub_idx: usize,
    kind: LayoutKind,
    host: &Matrix<S>,
    caller: AddressSpace,
) -> Result<StagedShard> {
    let panel = build_panel::<S>(&kind, host.rows(), host, sub_idx);
    let ptr = sub.alloc_scalars::<S>(sub_idx, panel.len())?;
    let staged = (|| -> Result<Option<IpcHandle>> {
        if !panel.is_empty() {
            sub.write_slice(ptr, 0, &panel)?;
            sub.charge_h2d(sub_idx, std::mem::size_of_val(panel.as_slice()))?;
        }
        if ctx.space != caller {
            let h = ctx.registry.export_bound(ctx.space, sub, ptr)?;
            ctx.node.metrics().add_ipc_export();
            Ok(Some(h))
        } else {
            Ok(None)
        }
    })();
    match staged {
        Ok(handle) => {
            ctx.record_staged(StagedAlloc { node: sub.clone(), ptr });
            Ok(StagedShard { ptr, handle })
        }
        Err(e) => {
            let _ = sub.free(ptr);
            Err(e)
        }
    }
}

impl<S: Scalar> DistWork for DistReq<S> {
    fn plan(&self, shared: &Shared, ndev: usize) -> Result<DistPlan> {
        let n = self.a.rows();
        let nrhs = self.rhs.as_ref().map(|b| b.cols()).unwrap_or(0);
        shared.plans.plan(
            self.routine.name(),
            n,
            nrhs,
            shared.cfg.tile,
            ndev,
            S::DTYPE,
            &shared.cfg.model,
            shared.node.topology(),
            shared.cfg.grid,
        )
    }

    fn execute(
        &self,
        shared: &Shared,
        live: &[usize],
        plan: &DistPlan,
        queue_wait: Duration,
    ) -> ExecResult {
        let t0 = Instant::now();
        let caller = shared.caller;
        let fp = &plan.footprint;
        let metrics = shared.node.metrics().clone();
        let mut opened: Vec<IpcHandle> = Vec::new();
        // (`StagedShard` is not `Clone`, hence no `vec![None; n]`.)
        let mut staged: Vec<Option<StagedShard>> = (0..live.len()).map(|_| None).collect();
        let attempt = (|| -> Result<DistOut<S>> {
            let n = self.a.rows();
            let ndev = live.len();
            // Degraded mode runs on a subset view that shares the live
            // devices' VRAM/clocks but excludes the dead ones. The
            // planned layout — 1D or a P×Q grid — spans exactly the
            // live set; workers stage (and IPC-export) its 1D panels
            // or 2D tile shards alike through `build_panel`.
            let sub = shared.node.subset(live)?;
            let kind = plan.kind;

            // 1. Every live worker stages its own shard in its own
            // process and ships a pointer (rank 0) or handle (others).
            let (tx, rx) = mpsc::channel::<(usize, Result<StagedShard>)>();
            for (i, &dev) in live.iter().enumerate() {
                let tx = tx.clone();
                let a = self.a.clone();
                let sub = sub.clone();
                let job: WorkerJob = Box::new(move |ctx| {
                    if !ctx.alive() {
                        // Dead process: dropping `tx` is the disconnect
                        // rank 0 observes.
                        return;
                    }
                    let res = stage_shard::<S>(ctx, &sub, i, kind, &a, caller);
                    let _ = tx.send((i, res));
                });
                // A closed mailbox drops the job (and its `tx`): the
                // missing reply is detected below.
                let _ = shared.workers[dev].send(job);
            }
            drop(tx);

            // Drain EVERY reply before acting on errors: a successfully
            // staged shard must land in `staged` so the teardown below
            // can hand it back to its worker even when a sibling failed.
            let mut stage_err: Option<Error> = None;
            for (i, res) in rx {
                match res {
                    Ok(sh) => staged[i] = Some(sh),
                    Err(e) => {
                        if stage_err.is_none() {
                            stage_err = Some(e);
                        }
                    }
                }
            }
            if let Some(e) = stage_err {
                return Err(e);
            }

            // 2. Rank 0 opens every foreign handle in its own space,
            // paying the modeled cudaIpc round-trip per handle — the
            // exact terms `Predictor::mpmd_overhead` projects.
            let per_handle = shared.cfg.model.ipc_export_s
                + shared.cfg.model.ipc_open_s
                + shared.node.topology().h2d_time(64);
            let mut panels = Vec::with_capacity(ndev);
            for (i, sh) in staged.iter().enumerate() {
                let sh = sh.as_ref().ok_or_else(|| {
                    Error::ipc(format!("worker {} died before publishing its shard", live[i]))
                })?;
                match sh.handle {
                    Some(h) => {
                        let ptr = shared.registry.open(caller, h)?;
                        opened.push(h);
                        metrics.add_ipc_open();
                        // The caller's process runs next to device 0.
                        shared.node.device(0)?.clock().advance(per_handle);
                        panels.push(ptr);
                    }
                    None => panels.push(sh.ptr),
                }
            }

            // 3. The single caller assembles the view and solves.
            let backend = SolverBackend::<S>::Native;
            let ctx =
                Ctx::with_pipeline(&sub, &shared.cfg.model, &backend, shared.cfg.pipeline);
            let mut dm = DistMatrix::<S>::from_panels(&sub, n, kind, panels)?;
            let solved = (|| -> Result<DistOut<S>> {
                // syevd runs on A directly — only the Cholesky family
                // factors first (parity with `SolveService::submit_syevd`
                // and the `JaxMg::syevd` entry point).
                if self.routine == DistRoutine::Syevd {
                    let vals = syevd_dist(&ctx, &mut dm)?;
                    return Ok(DistOut::Eig(vals, dm.gather()?));
                }
                potrf_dist(&ctx, &mut dm)?;
                match self.routine {
                    DistRoutine::Potrf => Ok(DistOut::Mat(dm.gather()?)),
                    DistRoutine::Potrs => {
                        let b = self.rhs.as_ref().expect("validated at submit");
                        Ok(DistOut::Mat(potrs_dist(&ctx, &dm, b)?))
                    }
                    DistRoutine::Potri => {
                        potri_dist(&ctx, &mut dm)?;
                        Ok(DistOut::Mat(dm.gather()?))
                    }
                    DistRoutine::Syevd => unreachable!("handled above"),
                }
            })();
            // The workers own the panels — never free them here.
            let _ = dm.into_panels();
            solved
        });
        // A router thread must survive anything a degraded solve can
        // throw (a killed worker's shards vanish mid-read): contain
        // unwinds here so teardown and in-flight accounting always run.
        let result: Result<DistOut<S>> =
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(attempt)) {
                Ok(r) => r,
                Err(p) => {
                    Err(Error::solver(format!("mpmd solve panicked: {}", panic_message(p))))
                }
            };

        // 4. Teardown on every path: close the caller's mappings, tear
        // down staged shards (revoke-on-free), release reservations.
        for h in &opened {
            if shared.registry.close(caller, *h).is_ok() {
                metrics.add_ipc_close();
            }
        }
        for (i, &dev) in live.iter().enumerate() {
            let wctx = &shared.workers[dev].ctx;
            if let Some(sh) = &staged[i] {
                wctx.release_staged(sh.ptr);
            }
            wctx.admission.release(fp.bytes(i));
        }
        shared.front.notify();

        match result {
            Ok(out) => {
                let exec = t0.elapsed();
                metrics
                    .add_service_completion(queue_wait.as_nanos() as u64, exec.as_nanos() as u64);
                let stats = SolveStats {
                    queue_wait,
                    exec,
                    batch_size: 1,
                    coalesce_wait_ns: 0,
                    grid: plan.grid,
                };
                self.publish_ok(out, stats);
                ExecResult::Published
            }
            Err(e) => {
                let dead: Vec<usize> =
                    live.iter().copied().filter(|&d| !shared.workers[d].alive()).collect();
                if dead.is_empty() {
                    // Terminal failure: counts as a completion, exactly
                    // like a failed solve on the SPMD front.
                    metrics.add_service_completion(
                        queue_wait.as_nanos() as u64,
                        t0.elapsed().as_nanos() as u64,
                    );
                    self.fail(format!("mpmd {} failed: {e}", self.routine.name()));
                    ExecResult::Published
                } else {
                    ExecResult::Requeue(dead)
                }
            }
        }
    }

    fn fail(&self, msg: String) {
        match &self.slot {
            DistSlot::Mat(slot) => publish_one(slot, Err(msg)),
            DistSlot::Eig(slot) => publish_one(slot, Err(msg)),
        }
    }
}

// ---------------------------------------------------------------------------
// Pinned pod requests (the coalesced small-solve path)
// ---------------------------------------------------------------------------

struct PodReq<S: Scalar> {
    routine: SmallRoutine,
    systems: Vec<Matrix<S>>,
    rhss: Vec<Option<Matrix<S>>>,
    slots: Vec<Slot<Matrix<S>>>,
    waits: Vec<u64>,
}

impl<S: Scalar> PodWork for PodReq<S> {
    fn bytes(&self) -> usize {
        // The pod is pinned to one device, so its reservation is the
        // whole-bucket arena: `Footprint::for_pod` over a single
        // "device" — one sizing formula for both fronts.
        let dims: Vec<(usize, usize)> = self
            .systems
            .iter()
            .zip(&self.rhss)
            .map(|(a, b)| (a.rows(), b.as_ref().map(|m| m.cols()).unwrap_or(0)))
            .collect();
        Footprint::for_pod(self.routine.name(), &dims, 1, S::DTYPE)
            .expect("SmallRoutine names are known to the workspace model")
            .bytes(0)
    }

    fn run(&self, ctx: &WorkerCtx, queue_wait: Duration) -> PodOutcome {
        let t0 = Instant::now();
        let occupancy = self.systems.len();
        let swept = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_bucket::<S>(
                self.routine,
                &ctx.node,
                &ctx.model,
                &self.systems,
                &self.rhss,
                Some(ctx.device),
            )
        }));
        match swept {
            Ok(Ok((results, makespan_ns))) => {
                let exec = t0.elapsed();
                let total_wait: u64 = self.waits.iter().sum();
                ctx.node.metrics().add_batch_bucket(occupancy as u64, total_wait, makespan_ns);
                ctx.node
                    .metrics()
                    .add_service_completion(queue_wait.as_nanos() as u64, exec.as_nanos() as u64);
                for ((slot, x), wait_ns) in
                    self.slots.iter().zip(results).zip(self.waits.iter().copied())
                {
                    let stats = SolveStats {
                        queue_wait,
                        exec,
                        batch_size: occupancy,
                        coalesce_wait_ns: wait_ns,
                        grid: (1, 1),
                    };
                    publish_one(slot, Ok((x, stats)));
                }
                PodOutcome::Published
            }
            _ => {
                if !ctx.alive() {
                    return PodOutcome::WorkerDead;
                }
                // A sweep aborts at its first failing system; rerun one
                // at a time, pinned to this device, so only the
                // culprit's waiter sees the failure. If the process
                // dies mid-loop, the unpublished tail re-enters the
                // frontend queue as a fresh pod on the other devices.
                for i in 0..occupancy {
                    if !ctx.alive() {
                        let tail = PodReq::<S> {
                            routine: self.routine,
                            systems: self.systems[i..].to_vec(),
                            rhss: self.rhss[i..].to_vec(),
                            slots: self.slots[i..].to_vec(),
                            waits: self.waits[i..].to_vec(),
                        };
                        ctx.node.metrics().add_mpmd_requeue();
                        let mut work = QueuedWork::fresh(WorkKind::Pod(Arc::new(tail)));
                        work.excluded.push(ctx.device);
                        work.attempts = 1;
                        if let Err(w) = ctx.front.enqueue(work) {
                            fail_work(w, "mpmd service shut down during retry".to_string());
                        } else {
                            ctx.node.metrics().add_service_submission();
                        }
                        break;
                    }
                    let one = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_bucket::<S>(
                            self.routine,
                            &ctx.node,
                            &ctx.model,
                            &self.systems[i..i + 1],
                            &self.rhss[i..i + 1],
                            Some(ctx.device),
                        )
                    }));
                    let exec = t0.elapsed();
                    let outcome = match one {
                        Ok(Ok((mut v, _))) => Ok((
                            v.pop().expect("batch of one"),
                            SolveStats {
                                queue_wait,
                                exec,
                                batch_size: 1,
                                coalesce_wait_ns: self.waits[i],
                                grid: (1, 1),
                            },
                        )),
                        Ok(Err(e)) => Err(format!("small solve failed: {e}")),
                        Err(p) => Err(panic_message(p)),
                    };
                    publish_one(&self.slots[i], outcome);
                }
                // One admitted pod, one completion — whichever path
                // resolved it (parity with the SPMD bucket flusher).
                ctx.node
                    .metrics()
                    .add_service_completion(queue_wait.as_nanos() as u64, t0.elapsed().as_nanos() as u64);
                PodOutcome::Published
            }
        }
    }

    fn fail(&self, msg: String) {
        publish_failure(&self.slots, msg);
    }
}

// ---------------------------------------------------------------------------
// Dispatcher
// ---------------------------------------------------------------------------

fn reserve_all(shared: &Shared, live: &[usize], fp: &Footprint) -> bool {
    for (i, &dev) in live.iter().enumerate() {
        if shared.workers[dev].ctx.admission.try_reserve(fp.bytes(i)).is_err() {
            for (j, &dj) in live.iter().enumerate().take(i) {
                shared.workers[dj].ctx.admission.release(fp.bytes(j));
            }
            return false;
        }
    }
    true
}

/// Route one popped work item. Returns `false` when the head could not
/// be admitted yet (it is back at the head; the dispatcher waits for a
/// release before retrying — strict FIFO, no starvation).
fn dispatch(shared: &Arc<Shared>, routers: &Arc<JobQueue>, work: QueuedWork) -> bool {
    let live = shared.live_workers(&work.excluded);
    let metrics = shared.node.metrics().clone();
    if live.is_empty() {
        let msg = format!(
            "no live workers left after {} attempt(s) (excluded: {:?})",
            work.attempts + 1,
            work.excluded
        );
        fail_work(work, msg);
        shared.front.complete();
        return true;
    }
    // Clone the routed payload out first so `work` can move into the
    // execution closures below.
    enum Routed {
        Dist(Arc<dyn DistWork>),
        Pod(Arc<dyn PodWork>),
    }
    let routed = match &work.kind {
        WorkKind::Dist(req) => Routed::Dist(req.clone()),
        WorkKind::Pod(pod) => Routed::Pod(pod.clone()),
    };
    match routed {
        Routed::Dist(req) => {
            // Plan over the live set: the selector (or the configured
            // override) picks the grid shape, and admission is against
            // the exact per-device shards of the planned layout.
            let plan = match req.plan(shared, live.len()) {
                Ok(plan) => plan,
                Err(e) => {
                    req.fail(format!("solve planning failed: {e}"));
                    shared.front.complete();
                    return true;
                }
            };
            // Fail fast when a live device could never hold its share —
            // waiting for releases would deadlock the queue head.
            for (i, &dev) in live.iter().enumerate() {
                if plan.footprint.bytes(i) > shared.workers[dev].ctx.admission.capacity() {
                    req.fail(format!(
                        "declared footprint ({} B) exceeds device {dev}'s capacity",
                        plan.footprint.bytes(i)
                    ));
                    shared.front.complete();
                    return true;
                }
            }
            if !reserve_all(shared, &live, &plan.footprint) {
                let mut st = shared.front.state.lock().unwrap();
                st.queue.push_front(work);
                st.in_flight -= 1;
                return false;
            }
            metrics.add_mpmd_routed(work.enqueued.elapsed().as_nanos() as u64);
            let shared2 = shared.clone();
            let _ = routers.submit(move || {
                let queue_wait = work.enqueued.elapsed();
                match req.execute(&shared2, &live, &plan, queue_wait) {
                    ExecResult::Published => shared2.front.complete(),
                    ExecResult::Requeue(dead) => {
                        shared2.node.metrics().add_mpmd_requeue();
                        shared2.front.requeue(work, &dead);
                    }
                }
            });
            true
        }
        Routed::Pod(pod) => {
            let bytes = pod.bytes();
            let mut cands: Vec<usize> = live
                .iter()
                .copied()
                .filter(|&d| bytes <= shared.workers[d].ctx.admission.capacity())
                .collect();
            if cands.is_empty() {
                pod.fail(format!("pod of {bytes} B exceeds every live device's capacity"));
                shared.front.complete();
                return true;
            }
            // Pin to the least-loaded live worker that admits the pod.
            cands.sort_by_key(|&d| (shared.workers[d].queue_depth(), d));
            let mut target = None;
            for &d in &cands {
                if shared.workers[d].ctx.admission.try_reserve(bytes).is_ok() {
                    target = Some(d);
                    break;
                }
            }
            let Some(dev) = target else {
                let mut st = shared.front.state.lock().unwrap();
                st.queue.push_front(work);
                st.in_flight -= 1;
                return false;
            };
            metrics.add_mpmd_routed(work.enqueued.elapsed().as_nanos() as u64);
            let job: WorkerJob = Box::new(move |ctx| {
                if !ctx.alive() {
                    // Draining a dead worker: hand the pod back.
                    ctx.admission.release(bytes);
                    ctx.node.metrics().add_mpmd_requeue();
                    ctx.front.requeue(work, &[ctx.device]);
                    return;
                }
                let queue_wait = work.enqueued.elapsed();
                match pod.run(ctx, queue_wait) {
                    PodOutcome::Published => {
                        ctx.admission.release(bytes);
                        ctx.front.complete();
                    }
                    PodOutcome::WorkerDead => {
                        ctx.admission.release(bytes);
                        ctx.node.metrics().add_mpmd_requeue();
                        ctx.front.requeue(work, &[ctx.device]);
                    }
                }
            });
            if let Err(job) = shared.workers[dev].send(job) {
                // Raced a death between admission and send: run the job
                // in dead mode right here — it releases the reservation
                // and re-queues the pod with this device excluded.
                job(&shared.workers[dev].ctx);
            }
            true
        }
    }
}

fn dispatcher_loop(shared: Arc<Shared>, small: Arc<Mutex<MpmdSmall>>, routers: Arc<JobQueue>) {
    loop {
        // Frontend-driven coalescer tick: dwell-expired buckets flush
        // even when no further submit arrives (the serve-loop twin of
        // the SPMD service's background flusher thread).
        flush_due_buckets(&shared, &small);
        let popped = {
            let mut st = shared.front.state.lock().unwrap();
            if st.shutdown && st.queue.is_empty() && st.in_flight == 0 {
                return;
            }
            match st.queue.pop_front() {
                Some(w) => {
                    st.in_flight += 1;
                    Some(w)
                }
                None => {
                    let _unused =
                        shared.front.cv.wait_timeout(st, Duration::from_millis(10)).unwrap();
                    None
                }
            }
        };
        let Some(work) = popped else { continue };
        if !dispatch(&shared, &routers, work) {
            // Head-of-line wait: capacity frees when something
            // completes; the release paths notify this condvar.
            let st = shared.front.state.lock().unwrap();
            let _unused = shared.front.cv.wait_timeout(st, Duration::from_millis(5)).unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// Small-solve coalescing state
// ---------------------------------------------------------------------------

/// One queued small request, type-erased so one planner holds every
/// dtype (the builder installed by the first push downcasts back).
type SmallPayload = Box<dyn Any + Send>;

/// Turns a flushed bucket + its payloads into a routable pod.
type PodBuilder = dyn Fn(FlushedBucket, Vec<SmallPayload>) -> QueuedWork + Send + Sync;

struct MpmdSmallJob<S: Scalar> {
    a: Matrix<S>,
    rhs: Option<Matrix<S>>,
    slot: Slot<Matrix<S>>,
}

struct MpmdSmall {
    planner: BatchPlanner,
    payloads: HashMap<u64, SmallPayload>,
    builders: HashMap<BucketKey, Arc<PodBuilder>>,
    /// Memoized `Predictor::batched_wins` per (routine, dtype, class).
    decisions: HashMap<(SmallRoutine, DType, u32), bool>,
}

fn pod_builder<S: Scalar>(routine: SmallRoutine) -> Arc<PodBuilder> {
    Arc::new(move |bucket: FlushedBucket, payloads: Vec<SmallPayload>| {
        let mut systems = Vec::with_capacity(payloads.len());
        let mut rhss = Vec::with_capacity(payloads.len());
        let mut slots = Vec::with_capacity(payloads.len());
        for p in payloads {
            let job = *p.downcast::<MpmdSmallJob<S>>().expect("bucket key pins the dtype");
            systems.push(job.a);
            rhss.push(job.rhs);
            slots.push(job.slot);
        }
        QueuedWork::fresh(WorkKind::Pod(Arc::new(PodReq::<S> {
            routine,
            systems,
            rhss,
            slots,
            waits: bucket.waits_ns,
        })))
    })
}

fn collect_ready(st: &mut MpmdSmall, bucket: FlushedBucket, out: &mut Vec<QueuedWork>) {
    let builder = st.builders.get(&bucket.key).expect("builder installed on first push").clone();
    let payloads: Vec<SmallPayload> =
        bucket.ids.iter().map(|id| st.payloads.remove(id).expect("payload stored")).collect();
    out.push(builder(bucket, payloads));
}

fn flush_due_buckets(shared: &Shared, small: &Mutex<MpmdSmall>) {
    let now_ns = shared.sim_now_ns();
    let mut ready = Vec::new();
    {
        let mut st = small.lock().unwrap();
        for key in st.planner.due(now_ns) {
            if let Some(bucket) = st.planner.flush(key, now_ns) {
                collect_ready(&mut st, bucket, &mut ready);
            }
        }
    }
    for w in ready {
        if let Err(w) = shared.front.enqueue(w) {
            fail_work(w, "mpmd service is shut down".to_string());
        } else {
            shared.node.metrics().add_service_submission();
        }
    }
}

// ---------------------------------------------------------------------------
// The service
// ---------------------------------------------------------------------------

/// The MPMD serving subsystem: one simulated process per GPU behind a
/// rank-0 frontend (see the module docs and `crate::serve`).
pub struct MpmdService {
    shared: Arc<Shared>,
    small: Arc<Mutex<MpmdSmall>>,
    routers: Option<Arc<JobQueue>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    worker_threads: Vec<std::thread::JoinHandle<()>>,
}

impl MpmdService {
    /// Serve `node` with the default configuration.
    pub fn new(node: SimNode) -> Self {
        Self::with_config(node, MpmdConfig::default())
    }

    /// Serve `node`: spawns one worker process per device, the router
    /// pool, and the rank-0 dispatcher.
    pub fn with_config(node: SimNode, cfg: MpmdConfig) -> Self {
        let registry = Arc::new(IpcRegistry::new());
        let front = Arc::new(FrontShared::new());
        let mut workers = Vec::new();
        let mut worker_threads = Vec::new();
        for d in 0..node.num_devices() {
            let ctx = WorkerCtx::new(
                d,
                node.clone(),
                registry.clone(),
                cfg.model.clone(),
                front.clone(),
            );
            let (link, thread) = spawn_worker(ctx);
            workers.push(link);
            worker_threads.push(thread);
        }
        let policy = cfg.policy;
        let routers_n = cfg.routers.max(1);
        let shared = Arc::new(Shared {
            node,
            registry,
            cfg,
            workers,
            front,
            plans: GridPlanCache::new(),
            caller: AddressSpace(0),
        });
        let small = Arc::new(Mutex::new(MpmdSmall {
            planner: BatchPlanner::new(policy),
            payloads: HashMap::new(),
            builders: HashMap::new(),
            decisions: HashMap::new(),
        }));
        let routers = Arc::new(JobQueue::new(routers_n));
        let dispatcher = {
            let shared = shared.clone();
            let small = small.clone();
            let routers = routers.clone();
            std::thread::spawn(move || dispatcher_loop(shared, small, routers))
        };
        MpmdService {
            shared,
            small,
            routers: Some(routers),
            dispatcher: Some(dispatcher),
            worker_threads,
        }
    }

    fn enqueue_dist<S: Scalar>(&self, req: DistReq<S>) -> Result<()> {
        let work = QueuedWork::fresh(WorkKind::Dist(Arc::new(req)));
        if let Err(w) = self.shared.front.enqueue(work) {
            fail_work(w, "mpmd service is shut down".to_string());
            return Err(Error::config("mpmd service is shut down"));
        }
        self.shared.node.metrics().add_service_submission();
        Ok(())
    }

    fn validate_square<S: Scalar>(a: &Matrix<S>) -> Result<usize> {
        let n = a.require_square()?;
        if n == 0 {
            return Err(Error::shape("cannot solve an empty system"));
        }
        Ok(n)
    }

    /// Distributed Cholesky factor: returns the factored matrix.
    pub fn submit_potrf<S: Scalar>(&self, a: Matrix<S>) -> Result<ServiceHandle<Matrix<S>>> {
        Self::validate_square(&a)?;
        let (handle, slot) = handle_pair::<Matrix<S>>();
        self.enqueue_dist(DistReq {
            routine: DistRoutine::Potrf,
            a: Arc::new(a),
            rhs: None,
            slot: DistSlot::Mat(slot),
        })?;
        Ok(handle)
    }

    /// Distributed solve `A·X = B` (factor + two-sweep solve).
    pub fn submit_potrs<S: Scalar>(
        &self,
        a: Matrix<S>,
        b: Matrix<S>,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        let n = Self::validate_square(&a)?;
        if b.rows() != n {
            return Err(Error::shape(format!("rhs has {} rows, matrix is {n}x{n}", b.rows())));
        }
        let (handle, slot) = handle_pair::<Matrix<S>>();
        self.enqueue_dist(DistReq {
            routine: DistRoutine::Potrs,
            a: Arc::new(a),
            rhs: Some(b),
            slot: DistSlot::Mat(slot),
        })?;
        Ok(handle)
    }

    /// Distributed SPD/HPD inverse.
    pub fn submit_potri<S: Scalar>(&self, a: Matrix<S>) -> Result<ServiceHandle<Matrix<S>>> {
        Self::validate_square(&a)?;
        let (handle, slot) = handle_pair::<Matrix<S>>();
        self.enqueue_dist(DistReq {
            routine: DistRoutine::Potri,
            a: Arc::new(a),
            rhs: None,
            slot: DistSlot::Mat(slot),
        })?;
        Ok(handle)
    }

    /// Distributed eigendecomposition: ascending eigenvalues +
    /// eigenvector columns.
    pub fn submit_syevd<S: Scalar>(
        &self,
        a: Matrix<S>,
    ) -> Result<ServiceHandle<(Vec<S::Real>, Matrix<S>)>> {
        Self::validate_square(&a)?;
        let (handle, slot) = handle_pair::<(Vec<S::Real>, Matrix<S>)>();
        self.enqueue_dist(DistReq {
            routine: DistRoutine::Syevd,
            a: Arc::new(a),
            rhs: None,
            slot: DistSlot::Eig(slot),
        })?;
        Ok(handle)
    }

    /// Submit a small solve: coalesced into a worker-pinned pod when
    /// the cost model says batching wins, routed distributed otherwise
    /// — the MPMD twin of `SolveService::submit_small`.
    pub fn submit_small<S: Scalar>(
        &self,
        routine: SmallRoutine,
        a: Matrix<S>,
        rhs: Option<Matrix<S>>,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        let n = Self::validate_square(&a)?;
        match (routine, &rhs) {
            (SmallRoutine::Potrs, None) => {
                return Err(Error::config("potrs needs a right-hand side"));
            }
            (SmallRoutine::Potrs, Some(b)) if b.rows() != n => {
                return Err(Error::shape(format!(
                    "rhs has {} rows, matrix is {n}x{n}",
                    b.rows()
                )));
            }
            (SmallRoutine::Potrf | SmallRoutine::Potri, Some(_)) => {
                return Err(Error::config("only potrs takes a right-hand side"));
            }
            _ => {}
        }
        // Capacity gate: a pinned pod concentrates the whole bucket on
        // ONE device (unlike the SPMD round-robin pod), so the
        // worst-case bucket is `max_batch` systems of this size-class
        // on a single device's VRAM.
        let nrhs = rhs.as_ref().map(|b| b.cols()).unwrap_or(1);
        let e = S::DTYPE.size_of();
        let class = size_class(n) as usize;
        let per_system = class * class * e
            + if matches!(routine, SmallRoutine::Potrs) { class * nrhs * e } else { 0 };
        let worst_bucket = self.shared.cfg.policy.max_batch * per_system;
        let max_cap = self
            .shared
            .workers
            .iter()
            .map(|w| w.ctx.admission.capacity())
            .max()
            .unwrap_or(0);
        let coalesce = worst_bucket <= max_cap
            && n <= self.shared.cfg.policy.small_dim
            && self.batched_decision::<S>(routine, class);
        if !coalesce {
            // The latency bound holds on every submit, whichever path
            // this request takes.
            self.flush_due_small();
            let dist = match routine {
                SmallRoutine::Potrf => DistRoutine::Potrf,
                SmallRoutine::Potrs => DistRoutine::Potrs,
                SmallRoutine::Potri => DistRoutine::Potri,
            };
            let (handle, slot) = handle_pair::<Matrix<S>>();
            self.enqueue_dist(DistReq {
                routine: dist,
                a: Arc::new(a),
                rhs,
                slot: DistSlot::Mat(slot),
            })?;
            return Ok(handle);
        }

        let (handle, slot) = handle_pair::<Matrix<S>>();
        let key = BucketKey::new(routine, S::DTYPE, n);
        let now_ns = self.shared.sim_now_ns();
        let mut ready = Vec::new();
        {
            let mut st = self.small.lock().unwrap();
            st.builders.entry(key).or_insert_with(|| pod_builder::<S>(routine));
            let (id, flushed) = st.planner.push(key, now_ns);
            st.payloads.insert(id, Box::new(MpmdSmallJob::<S> { a, rhs, slot }));
            if let Some(bucket) = flushed {
                collect_ready(&mut st, bucket, &mut ready);
            }
            for k in st.planner.due(now_ns) {
                if let Some(bucket) = st.planner.flush(k, now_ns) {
                    collect_ready(&mut st, bucket, &mut ready);
                }
            }
        }
        for w in ready {
            // Submission accounting is pod-granular, matching the SPMD
            // flusher's one-enqueue-per-bucket semantics.
            if let Err(w) = self.shared.front.enqueue(w) {
                fail_work(w, "mpmd service is shut down".to_string());
            } else {
                self.shared.node.metrics().add_service_submission();
            }
        }
        Ok(handle)
    }

    fn batched_decision<S: Scalar>(&self, routine: SmallRoutine, class: usize) -> bool {
        let key = (routine, S::DTYPE, class as u32);
        let mut st = self.small.lock().unwrap();
        if let Some(&win) = st.decisions.get(&key) {
            return win;
        }
        let predictor = Predictor {
            model: self.shared.cfg.model.clone(),
            topo: self.shared.node.topology().clone(),
            dtype: S::DTYPE,
        };
        let win = predictor.batched_wins(
            routine.name(),
            class,
            1,
            self.shared.cfg.tile,
            self.shared.workers.len(),
            self.shared.cfg.policy.max_batch,
        );
        st.decisions.insert(key, win);
        win
    }

    /// Flush buckets whose oldest request dwelled past the bound.
    pub fn flush_due_small(&self) {
        flush_due_buckets(&self.shared, &self.small);
    }

    /// Force-flush every pending coalescer bucket.
    pub fn flush_small(&self) {
        let now_ns = self.shared.sim_now_ns();
        let mut ready = Vec::new();
        {
            let mut st = self.small.lock().unwrap();
            for bucket in st.planner.flush_all(now_ns) {
                collect_ready(&mut st, bucket, &mut ready);
            }
        }
        for w in ready {
            if let Err(w) = self.shared.front.enqueue(w) {
                fail_work(w, "mpmd service is shut down".to_string());
            } else {
                self.shared.node.metrics().add_service_submission();
            }
        }
    }

    /// Small solves waiting in the coalescer (not yet flushed).
    pub fn pending_small(&self) -> usize {
        self.small.lock().unwrap().planner.pending()
    }

    /// Simulate worker `d`'s process dying right now: its staged
    /// shards vanish (exports revoked), pending mailbox work re-routes,
    /// and in-flight solves that touched its shards re-queue with the
    /// device excluded.
    pub fn kill_worker(&self, d: usize) -> Result<()> {
        let link = self
            .shared
            .workers
            .get(d)
            .ok_or(Error::InvalidDevice { device: d, count: self.shared.workers.len() })?;
        link.kill();
        Ok(())
    }

    /// Arm the chaos fault injector: the next job worker `d` processes
    /// panics, exercising the panic-death path end to end.
    pub fn inject_worker_fault(&self, d: usize) -> Result<()> {
        let link = self
            .shared
            .workers
            .get(d)
            .ok_or(Error::InvalidDevice { device: d, count: self.shared.workers.len() })?;
        link.ctx.arm_fault();
        Ok(())
    }

    /// Devices whose worker process is alive.
    pub fn alive_workers(&self) -> Vec<usize> {
        self.shared.live_workers(&[])
    }

    /// Per-worker mailbox depths (the queue-depth gauge behind the
    /// `mpmd_peak_worker_queue` metric).
    pub fn worker_queue_depths(&self) -> Vec<usize> {
        self.shared.workers.iter().map(|w| w.queue_depth()).collect()
    }

    /// Per-worker reserved bytes (each worker's own accountant).
    pub fn reserved(&self) -> Vec<usize> {
        self.shared.workers.iter().map(|w| w.ctx.admission.reserved()).collect()
    }

    /// Per-worker reservation high-water marks.
    pub fn peak_reserved(&self) -> Vec<usize> {
        self.shared.workers.iter().map(|w| w.ctx.admission.peak_reserved()).collect()
    }

    /// Requests queued at the frontend (not yet dispatched).
    pub fn pending(&self) -> usize {
        self.shared.front.state.lock().unwrap().queue.len()
    }

    /// Requests dispatched and not yet resolved.
    pub fn in_flight(&self) -> usize {
        self.shared.front.state.lock().unwrap().in_flight
    }

    /// The node this service serves.
    pub fn node(&self) -> &SimNode {
        &self.shared.node
    }

    /// The active configuration.
    pub fn config(&self) -> &MpmdConfig {
        &self.shared.cfg
    }

    /// The IPC registry (per-process open/export accounting lives
    /// here; see `crate::ipc`).
    pub fn registry(&self) -> &Arc<IpcRegistry> {
        &self.shared.registry
    }

    /// Block until every submitted request has resolved (published to
    /// its handle) — partial coalescer buckets are force-flushed first.
    pub fn drain(&self) {
        self.flush_small();
        let mut st = self.shared.front.state.lock().unwrap();
        while !st.queue.is_empty() || st.in_flight > 0 {
            let (guard, _) =
                self.shared.front.cv.wait_timeout(st, Duration::from_millis(20)).unwrap();
            st = guard;
        }
    }
}

impl Drop for MpmdService {
    fn drop(&mut self) {
        // Flush stragglers so their waiters resolve, then let the
        // dispatcher drain the queue to zero before stopping anything.
        self.flush_small();
        {
            let mut st = self.shared.front.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.front.cv.notify_all();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // Routers next (their jobs need live workers), workers last.
        self.routers = None;
        for w in &self.shared.workers {
            w.close();
        }
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
    }
}
