//! The rank-0 frontend: request queue, failure-aware routing, and the
//! single-caller solve path.
//!
//! [`MpmdService`] owns the SLO-aware request queue (an
//! [`SloQueue`] shared with the SPMD front — FIFO by default, EDF/SJF
//! under [`SchedPolicy::EdfSjf`](crate::coordinator::SchedPolicy),
//! with the same anti-starvation barrier and per-tenant quotas). A
//! dispatcher thread admits the scheduled head against the **workers'
//! own** per-device accountants (all-or-rollback across the live set
//! for distributed solves, a single least-loaded worker for pinned
//! pods), then hands execution off:
//!
//! * **distributed solves** run on a router pool as the single caller —
//!   live workers stage their shards locally and export them, rank 0
//!   opens the foreign handles (charging the modeled `cudaIpc`
//!   round-trip, [`Predictor::mpmd_overhead`]'s exact terms), assembles
//!   the pointers into a [`DistMatrix`] view, and invokes
//!   `potrf/potrs/potri/syevd_dist`;
//! * **small solves** coalesce in a [`BatchPlanner`] exactly as in the
//!   SPMD service; a flushed bucket becomes one pod **pinned to one
//!   worker**, swept on that worker's thread.
//!
//! ## Failure-aware routing
//!
//! Worker death (panic or [`MpmdService::kill_worker`]) never loses a
//! request. Every dispatched work item is accounted in-flight until it
//! either publishes or re-enters the queue; the re-entry paths are:
//!
//! * a staging reply never arrives (dead worker's mailbox dropped the
//!   job) — the router sees the disconnect;
//! * the solve fails and some participant is no longer alive (its
//!   freed shards poisoned the solve) — re-queued with the dead
//!   devices excluded;
//! * a pod job lands on (or is draining from) a dead worker — it hands
//!   itself back for re-routing, excluding that device;
//! * a degraded pod rerun dies mid-loop — the unpublished tail
//!   re-enters as a fresh pod on the remaining devices.
//!
//! Retries shrink the live set monotonically (excluded devices
//! accumulate), so routing terminates: either a retry completes on the
//! remaining devices or the request resolves with the typed
//! [`ServeError::NoLiveWorkers`] — re-queueing against an empty live
//! set would spin forever, so the dispatcher surfaces it instead.
//!
//! Straggler injection ([`MpmdService::inject_straggler`]) generalizes
//! the kill drill: a dragged device clock slows every charge it hosts,
//! and deadline-miss accounting relaxes by
//! [`SchedConfig::degrade_factor`] while any straggler is active.
//!
//! ## Observability
//!
//! Every submission carries a [`TraceId`](crate::obs::TraceId): spans
//! cover queue-wait, staging, `cudaIpc` opens, solver stages, and the
//! request root; admissions, cache probes, requeues, kills, and
//! stragglers land in the decision log. The tracer is passive (no
//! simulated clock moves) and off by default; observed-vs-predicted
//! drift optionally feeds back into the queue estimates via
//! [`MpmdConfig::drift_correction`]. See `crate::obs` and
//! `OBSERVABILITY.md`.
//!
//! [`Predictor::mpmd_overhead`]: crate::costmodel::Predictor::mpmd_overhead
//! [`BatchPlanner`]: crate::batch::BatchPlanner

use super::worker::{spawn_worker, StagedAlloc, WorkerCtx, WorkerJob, WorkerLink};
use crate::batch::{
    flusher_tick, run_bucket, size_class, BatchPlanner, BatchPolicy, BucketKey, FlushedBucket,
    SmallRoutine,
};
use crate::coordinator::{
    handle_pair, publish_error, publish_one, secs_to_ns, DistPlan, FactorCache, FactorEntry,
    FactorKey, Footprint, GridPlanCache, JobQueue, NumericPolicy, SchedConfig, ServeError,
    ServiceHandle, Slo, SloClass, Slot, SloQueue, SloTicket, SolveStats, TenantQuotas,
};
pub use crate::coordinator::DistRoutine;
use crate::coordinator::panic_message;
use crate::costmodel::{GpuCostModel, Predictor};
use crate::device::{DevPtr, SimNode};
use crate::error::{Error, Result};
use crate::ipc::{AddressSpace, IpcHandle, IpcRegistry};
use crate::linalg::Matrix;
use crate::obs::{DriftKey, SpanId, TraceId, Tracer};
use crate::scalar::{DType, Scalar};
use crate::solver::{
    lift_timeline_spans, potrf_dist, potri_dist, potrs_dist, syevd_dist, Ctx, MixedCapable,
    MixedRun, PipelineConfig, Precision, RefineOptions, SolverBackend, DEFAULT_REFINE_CAP,
    DEFAULT_REFINE_TOL,
};
use crate::tile::{build_panel, DistMatrix, LayoutKind};
use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

/// Configuration of the MPMD serving subsystem.
#[derive(Clone, Debug)]
pub struct MpmdConfig {
    /// `T_A` of the distributed solve layout; also anchors the default
    /// smallness cut (`small_dim = 4·tile`).
    pub tile: usize,
    /// Cost model for solve charges, the batched-vs-distributed
    /// dispatch decision, and the `cudaIpc` round-trip charge.
    pub model: GpuCostModel,
    /// Timing schedule of the distributed solves (barrier by default,
    /// so MPMD results are bitwise-comparable to the seed schedule).
    pub pipeline: PipelineConfig,
    /// Coalescing knobs of the small-solve path.
    pub policy: BatchPolicy,
    /// Router threads executing distributed solves as the single
    /// caller (bounds distributed solves in flight).
    pub routers: usize,
    /// Process-grid override for distributed solves: `None` lets the
    /// shared planner pick `P × Q` per request (over the **live**
    /// worker set — a shrunk set is re-planned); `Some((p, q))` pins
    /// it (p·q must equal the live worker count at dispatch).
    pub grid: Option<(usize, usize)>,
    /// Scheduling policy of the frontend queue — the same
    /// [`SchedConfig`] the SPMD front takes (FIFO by default).
    pub sched: SchedConfig,
    /// Keep Cholesky factors resident on the workers that computed
    /// them: a repeat `potrf/potrs/potri` against the same `A` skips
    /// staging and factorization and runs only the triangular tail on
    /// the resident shards (the MPMD twin of
    /// [`SmallConfig::factor_cache`](crate::coordinator::SmallConfig)).
    /// Resident bytes stay charged against the owning workers'
    /// accountants; admission pressure evicts by recompute-cost ×
    /// reuse. Off by default.
    pub factor_cache: bool,
    /// Feed observed-vs-predicted drift back into the queue estimates:
    /// once a `(routine, dtype, n, grid)` key has accumulated enough
    /// samples in the node's [`DriftMonitor`](crate::obs::DriftMonitor),
    /// new submissions rank by the drift-corrected makespan instead of
    /// the raw Predictor figure. Lookahead-pipelined fronts benefit
    /// most — the barrier-modeled estimate systematically overshoots
    /// the pipelined execution. Off by default (bitwise parity with
    /// the uncorrected queue order).
    pub drift_correction: bool,
}

impl MpmdConfig {
    /// Defaults anchored at tile size `tile` (`small_dim = 4·tile`).
    pub fn with_tile(tile: usize) -> Self {
        let policy = BatchPolicy { small_dim: 4 * tile, ..BatchPolicy::default() };
        MpmdConfig {
            tile,
            model: GpuCostModel::h200(),
            pipeline: PipelineConfig::barrier(),
            policy,
            routers: 2,
            grid: None,
            sched: SchedConfig::default(),
            factor_cache: false,
            drift_correction: false,
        }
    }
}

impl Default for MpmdConfig {
    fn default() -> Self {
        Self::with_tile(64)
    }
}

// `DistRoutine` lives in `coordinator::admit` (shared with the SPMD
// front's `SolveService::submit_dist`) and is re-exported above.

// ---------------------------------------------------------------------------
// Frontend shared state (queue + wake-ups)
// ---------------------------------------------------------------------------

struct FrontState {
    queue: SloQueue<QueuedWork>,
    in_flight: usize,
    shutdown: bool,
}

/// The rank-0 frontend state workers and routers wake each other
/// through: the SLO-aware request queue, the in-flight count, and the
/// one condvar behind every release/completion/death notification.
pub(crate) struct FrontShared {
    state: Mutex<FrontState>,
    cv: Condvar,
}

impl FrontShared {
    fn new(sched: SchedConfig) -> Self {
        FrontShared {
            state: Mutex::new(FrontState {
                queue: SloQueue::new(sched.policy, sched.max_skips),
                in_flight: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Wake the dispatcher (capacity released, worker died, ...).
    pub(crate) fn notify(&self) {
        self.cv.notify_all();
    }

    /// One dispatched work item finished (published its outcome).
    pub(crate) fn complete(&self) {
        let mut st = self.state.lock().unwrap();
        st.in_flight -= 1;
        drop(st);
        self.cv.notify_all();
    }

    /// A dispatched work item failed on dead devices: exclude them and
    /// restore it under its original ticket for re-routing — the
    /// request keeps its queue age (sequence number and skip count).
    pub(crate) fn requeue(&self, ticket: SloTicket, mut work: QueuedWork, dead: &[usize]) {
        for &d in dead {
            if !work.excluded.contains(&d) {
                work.excluded.push(d);
            }
        }
        let mut st = self.state.lock().unwrap();
        st.queue.restore(ticket, work);
        st.in_flight -= 1;
        drop(st);
        self.cv.notify_all();
    }

    /// Enqueue new work at cost-model time `now_ns`; hands the work
    /// back when the service is already shut down (the caller fails
    /// its waiters).
    pub(crate) fn enqueue(
        &self,
        work: QueuedWork,
        now_ns: u64,
    ) -> std::result::Result<(), QueuedWork> {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return Err(work);
        }
        st.queue.push_back(work.slo, work.est_ns, now_ns, work);
        drop(st);
        self.cv.notify_all();
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Work items
// ---------------------------------------------------------------------------

/// How a distributed work item ended.
pub(crate) enum ExecResult {
    /// Outcome published to the waiters (success or terminal failure).
    Published,
    /// Worker death poisoned the attempt: re-queue excluding the dead.
    Requeue(Vec<usize>),
}

/// How a worker-executed pod ended.
pub(crate) enum PodOutcome {
    Published,
    WorkerDead,
}

/// A distributed solve routed by the frontend (type-erased over dtype).
pub(crate) trait DistWork: Send + Sync {
    /// Plan the solve over the current live set: grid shape (selector
    /// or [`MpmdConfig::grid`] override), layout, exact footprint.
    fn plan(&self, shared: &Shared, ndev: usize) -> Result<DistPlan>;
    fn execute(
        &self,
        shared: &Shared,
        live: &[usize],
        plan: &DistPlan,
        ticket: &SloTicket,
    ) -> ExecResult;
    fn fail(&self, err: ServeError);
    /// The request's trace identity (nulls when tracing is off).
    fn ids(&self) -> (TraceId, SpanId);
}

/// A coalesced pod pinned to one worker (type-erased over dtype).
pub(crate) trait PodWork: Send + Sync {
    /// Arena bytes the pod needs on its single target device.
    fn bytes(&self) -> usize;
    fn run(&self, ctx: &WorkerCtx, ticket: &SloTicket, sched: SchedConfig) -> PodOutcome;
    fn fail(&self, err: ServeError);
    /// The request's trace identity (nulls when tracing is off).
    fn ids(&self) -> (TraceId, SpanId);
}

pub(crate) enum WorkKind {
    Dist(Arc<dyn DistWork>),
    Pod(Arc<dyn PodWork>),
}

/// One queued request plus its routing state. The enqueue timestamp
/// lives on the [`SloTicket`] the queue mints (cost-model ns — the
/// wall-clock `Instant` it replaced mixed time bases with the
/// simulated solve clock).
pub(crate) struct QueuedWork {
    kind: WorkKind,
    /// Devices excluded by prior failures (grows monotonically).
    excluded: Vec<usize>,
    /// SLO the queue ticket is minted from.
    slo: Slo,
    /// Predictor makespan estimate for SJF ordering (0 = unknown).
    est_ns: u64,
}

impl QueuedWork {
    fn fresh(kind: WorkKind, slo: Slo, est_ns: u64) -> Self {
        QueuedWork { kind, excluded: Vec::new(), slo, est_ns }
    }
}

/// Close a request's root span on a terminal failure path, so every
/// submission — even one that never dispatched — yields exactly one
/// complete span tree. No-op for a null trace.
fn close_failed_root(tracer: &Tracer, trace: TraceId, root: SpanId, now_ns: u64) {
    if trace.0 != 0 {
        tracer.close_root(trace, root, "request:failed", 0, now_ns, now_ns, 0, 0);
    }
}

/// Fail every waiter of a work item that can no longer be routed.
fn fail_work(work: QueuedWork, err: ServeError, tracer: &Tracer, now_ns: u64) {
    let (trace, root) = match &work.kind {
        WorkKind::Dist(req) => req.ids(),
        WorkKind::Pod(pod) => pod.ids(),
    };
    close_failed_root(tracer, trace, root, now_ns);
    match work.kind {
        WorkKind::Dist(req) => req.fail(err),
        WorkKind::Pod(pod) => pod.fail(err),
    }
}

/// Completion-side accounting shared by routers and worker pods: the
/// `service_*` aggregates plus the per-class latency histogram and
/// deadline-miss counter, all in cost-model ns. A deadline is judged
/// against the latency budget it implied at enqueue
/// (`deadline − enqueue`), scaled by [`SchedConfig::degrade_factor`]
/// while any device clock runs with straggler drag — mirrors the SPMD
/// front's accounting exactly.
fn note_completion(
    node: &SimNode,
    sched: &SchedConfig,
    ticket: &SloTicket,
    queue_wait_ns: u64,
    exec_ns: u64,
) {
    let m = node.metrics();
    m.add_service_completion(queue_wait_ns, exec_ns);
    let latency_ns = queue_wait_ns.saturating_add(exec_ns);
    let missed = match ticket.slo.deadline_ns {
        Some(d) => {
            let degraded = (0..node.num_devices())
                .any(|dev| node.device(dev).map(|g| g.clock().drag() > 1.0).unwrap_or(false));
            let budget = d.saturating_sub(ticket.enq_ns);
            let scale = if degraded { sched.degrade_factor } else { 1.0 };
            latency_ns as f64 > budget as f64 * scale
        }
        None => false,
    };
    m.record_class_latency(ticket.slo.class, latency_ns, missed);
}

// ---------------------------------------------------------------------------
// Shared service state
// ---------------------------------------------------------------------------

/// Everything the dispatcher, routers, and worker jobs share.
pub(crate) struct Shared {
    node: SimNode,
    registry: Arc<IpcRegistry>,
    cfg: MpmdConfig,
    workers: Vec<WorkerLink>,
    front: Arc<FrontShared>,
    /// Memoized grid-shape selections for the distributed planner
    /// (keyed per live-set size, so degraded-mode retries re-plan).
    plans: GridPlanCache,
    /// The frontend's (rank 0's) address space: worker 0 is a thread of
    /// this process, so its shard needs no IPC export.
    caller: AddressSpace,
    /// Per-tenant admitted-footprint quotas ([`SchedConfig::tenant_quota`]).
    quotas: TenantQuotas,
    /// Monotonic watermark over [`SimNode::sim_time_ns`]: concurrent
    /// device-clock advances may briefly lower the max-over-clocks
    /// reading between two calls, and queue-age arithmetic needs a
    /// non-decreasing clock.
    last_seen_ns: AtomicU64,
    /// Resident Cholesky factors ([`MpmdConfig::factor_cache`]): L's
    /// shards stay in the workers' staged ledgers, their bytes stay
    /// reserved under the owning workers' accountants, and rank 0
    /// re-opens the stored IPC handles on a hit. Lock order: cache
    /// before `front.state`, never held across a solve.
    cache: Mutex<FactorCache<MpmdFactor>>,
}

/// A resident distributed factor. Layout position `i` of the cached
/// [`LayoutKind`] lives on device `devices[i]`: `ptrs[i]` is the
/// worker-staged shard (still in that worker's ledger — teardown goes
/// through `release_staged`, revoke-on-free included) and `handles[i]`
/// the export rank 0 re-opens on a hit (`None` for the caller's own
/// worker 0).
#[derive(Clone, Debug)]
struct MpmdFactor {
    devices: Vec<usize>,
    ptrs: Vec<DevPtr>,
    handles: Vec<Option<IpcHandle>>,
}

impl Shared {
    fn live_workers(&self, excluded: &[usize]) -> Vec<usize> {
        (0..self.workers.len())
            .filter(|d| self.workers[*d].alive() && !excluded.contains(d))
            .collect()
    }

    /// Integer cost-model nanoseconds, monotone non-decreasing. (The
    /// float round-trip this replaced — `(sim_time() * 1e9).round()` —
    /// lost precision above 2^53 ns and could regress between calls.)
    fn sim_now_ns(&self) -> u64 {
        let now = self.node.sim_time_ns();
        let prev = self.last_seen_ns.fetch_max(now, Ordering::AcqRel);
        now.max(prev)
    }

    /// Probe the factor cache for a resident L staged over exactly
    /// `live`. The entry is pinned until [`Self::unpin_factor`].
    /// Staleness — a participant died, the live set shifted, or a
    /// shard was reclaimed — is validated lazily here: a stale entry
    /// is doomed and torn down, and the probe reports a miss.
    fn probe_factor(&self, key: &FactorKey, live: &[usize]) -> Option<MpmdFactor> {
        let (fac, _) = self.cache.lock().unwrap().probe(key)?;
        // `DevPtr`s are view-relative: the shards were allocated
        // through a subset view over `live`, so liveness is checked
        // through an identical view, never the full node.
        let valid = fac.devices == live
            && fac.devices.iter().all(|&d| self.workers[d].alive())
            && self
                .node
                .subset(live)
                .map(|sub| fac.ptrs.iter().all(|&p| sub.ptr_exists(p)))
                .unwrap_or(false);
        if valid {
            return Some(fac);
        }
        let doomed = {
            let mut cache = self.cache.lock().unwrap();
            cache.invalidate(|k, _| k == key);
            cache.unpin(key)
        };
        if let Some(e) = doomed {
            self.teardown_factor(&e);
        }
        None
    }

    /// Drop the pin taken by [`Self::probe_factor`]; an entry doomed
    /// while the hit was in flight is torn down here.
    fn unpin_factor(&self, key: &FactorKey) {
        let doomed = self.cache.lock().unwrap().unpin(key);
        if let Some(e) = doomed {
            self.teardown_factor(&e);
        }
    }

    /// Admit a just-computed factor into residency. The shards stay in
    /// their workers' staged ledgers; their bytes move from the solve's
    /// footprint reservation to the cache's resident charge, so the
    /// caller releases only `footprint − resident` per device. Returns
    /// the per-position resident bytes when kept, `None` when refused
    /// (first insert wins — a racing duplicate tears down normally).
    fn insert_factor(
        &self,
        key: FactorKey,
        kind: LayoutKind,
        fac: MpmdFactor,
        recompute_ns: u64,
    ) -> Option<Vec<usize>> {
        let resident = Footprint::for_cached_factor(&kind, key.n, key.dtype).into_per_device();
        let bytes: usize = resident.iter().sum();
        let refused =
            self.cache.lock().unwrap().insert(key, fac, kind, resident.clone(), recompute_ns);
        if refused.is_some() {
            return None;
        }
        self.node.metrics().add_cache_resident_bytes(bytes as i64);
        Some(resident)
    }

    /// Tear down a doomed/evicted/drained entry: hand each shard back
    /// to its worker's staged ledger (revoke-on-free; idempotent when
    /// death already reclaimed it) and release the resident charge
    /// from that worker's accountant.
    fn teardown_factor(&self, e: &FactorEntry<MpmdFactor>) {
        let fac = &e.payload;
        for (i, &dev) in fac.devices.iter().enumerate() {
            if let Some(w) = self.workers.get(dev) {
                w.ctx.release_staged(fac.ptrs[i]);
                w.ctx.admission.release(e.resident[i]);
            }
        }
        self.node.metrics().add_cache_resident_bytes(-(e.resident_bytes() as i64));
        self.front.notify();
    }

    /// Evict the lowest-value resident factor (recompute-cost × reuse,
    /// LRU on ties). Returns whether a victim's bytes were released.
    fn evict_factor(&self) -> bool {
        let victim = self.cache.lock().unwrap().pop_victim();
        match victim {
            Some((_, e)) => {
                let bytes = e.resident_bytes();
                self.teardown_factor(&e);
                self.node.metrics().add_cache_eviction();
                let tr = self.node.tracer();
                if tr.enabled() {
                    tr.decision(
                        TraceId(0),
                        self.sim_now_ns(),
                        "evict",
                        format!("admission pressure freed {bytes} B of resident factor"),
                    );
                }
                true
            }
            None => false,
        }
    }

    /// Drop every cached factor with a shard on device `d` — worker
    /// death or a straggler-degraded view invalidates its residency.
    /// Pinned entries (a hit in flight) are doomed and torn down at
    /// unpin instead.
    fn invalidate_factors_on(&self, d: usize) {
        let dead = self.cache.lock().unwrap().invalidate(|_, e| e.payload.devices.contains(&d));
        let tr = self.node.tracer();
        if tr.enabled() && !dead.is_empty() {
            tr.decision(
                TraceId(0),
                self.sim_now_ns(),
                "invalidate",
                format!("{} resident factor(s) touching device {d} dropped", dead.len()),
            );
        }
        for (_, e) in dead {
            self.teardown_factor(&e);
        }
    }
}

// ---------------------------------------------------------------------------
// Distributed solve requests
// ---------------------------------------------------------------------------

enum DistSlot<S: Scalar> {
    Mat(Slot<Matrix<S>>),
    Eig(Slot<(Vec<S::Real>, Matrix<S>)>),
}

enum DistOut<S: Scalar> {
    Mat(Matrix<S>),
    Eig(Vec<S::Real>, Matrix<S>),
}

struct DistReq<S: Scalar> {
    routine: DistRoutine,
    a: Arc<Matrix<S>>,
    rhs: Option<Matrix<S>>,
    slot: DistSlot<S>,
    /// Tolerance/condition budget carried from the submit [`Slo`];
    /// `Some` routes potrs through [`Precision::Mixed`] when the cost
    /// model predicts a win. Retries re-plan with the same policy.
    numeric: Option<NumericPolicy>,
    /// Trace identity, minted in `enqueue_dist` (nulls when tracing is
    /// off). Degraded-mode retries re-execute the same `DistReq`, so
    /// every attempt lands in one span tree and the root closes exactly
    /// once — at publish or terminal failure.
    trace: TraceId,
    root: SpanId,
}

impl<S: Scalar> DistReq<S> {
    fn publish_ok(&self, out: DistOut<S>, stats: SolveStats) {
        match (&self.slot, out) {
            (DistSlot::Mat(slot), DistOut::Mat(x)) => publish_one(slot, Ok((x, stats))),
            (DistSlot::Eig(slot), DistOut::Eig(vals, vecs)) => {
                publish_one(slot, Ok(((vals, vecs), stats)))
            }
            _ => unreachable!("routine determines the output shape"),
        }
    }
}

/// One worker's staged shard as reported back to rank 0.
struct StagedShard {
    ptr: DevPtr,
    handle: Option<IpcHandle>,
}

/// Worker-side shard staging: build the panel for `sub_idx` of the
/// layout, allocate + upload it on this worker's device (through the
/// possibly-degraded node view), and export it unless this *is* the
/// caller's process.
fn stage_shard<S: Scalar>(
    ctx: &WorkerCtx,
    sub: &SimNode,
    sub_idx: usize,
    kind: LayoutKind,
    host: &Matrix<S>,
    caller: AddressSpace,
) -> Result<StagedShard> {
    let panel = build_panel::<S>(&kind, host.rows(), host, sub_idx);
    let ptr = sub.alloc_scalars::<S>(sub_idx, panel.len())?;
    let staged = (|| -> Result<Option<IpcHandle>> {
        if !panel.is_empty() {
            sub.write_slice(ptr, 0, &panel)?;
            sub.charge_h2d(sub_idx, std::mem::size_of_val(panel.as_slice()))?;
        }
        if ctx.space != caller {
            let h = ctx.registry.export_bound(ctx.space, sub, ptr)?;
            ctx.node.metrics().add_ipc_export();
            Ok(Some(h))
        } else {
            Ok(None)
        }
    })();
    match staged {
        Ok(handle) => {
            ctx.record_staged(StagedAlloc { node: sub.clone(), ptr });
            Ok(StagedShard { ptr, handle })
        }
        Err(e) => {
            let _ = sub.free(ptr);
            Err(e)
        }
    }
}

impl<S: Scalar + MixedCapable> DistWork for DistReq<S> {
    fn plan(&self, shared: &Shared, ndev: usize) -> Result<DistPlan> {
        let n = self.a.rows();
        let nrhs = self.rhs.as_ref().map(|b| b.cols()).unwrap_or(0);
        shared.plans.plan_numeric(
            self.routine.name(),
            n,
            nrhs,
            shared.cfg.tile,
            ndev,
            S::DTYPE,
            &shared.cfg.model,
            shared.node.topology(),
            shared.cfg.grid,
            if self.routine == DistRoutine::Potrs { self.numeric } else { None },
        )
    }

    fn execute(
        &self,
        shared: &Shared,
        live: &[usize],
        plan: &DistPlan,
        ticket: &SloTicket,
    ) -> ExecResult {
        let t0_ns = shared.sim_now_ns();
        let queue_wait_ns = t0_ns.saturating_sub(ticket.enq_ns);
        let caller = shared.caller;
        // The fabric router may confine the plan to one island: it
        // spans `plan.ndev` devices (a prefix of the live set) with
        // zero-byte footprint padding on the rest. Stage and solve on
        // that prefix only — the padded entries reserved nothing, so
        // skipping their release below is also exact.
        let live: &[usize] = &live[..plan.ndev.min(live.len())];
        let fp = &plan.footprint;
        let metrics = shared.node.metrics().clone();
        let tracer = shared.node.tracer().clone();
        let trace = self.trace;
        if trace.0 != 0 {
            // One queue-wait span per attempt: a requeued request waits
            // again, and both waits belong to the same span tree.
            tracer.span(
                trace,
                self.root,
                "queue-wait",
                "sched",
                0,
                "requests",
                ticket.enq_ns,
                t0_ns,
                0,
                0,
            );
            if shared.cfg.pipeline.is_pipelined() {
                tracer.decision(
                    trace,
                    t0_ns,
                    "skip-barrier",
                    format!(
                        "lookahead depth {} pipelines panel/update stages",
                        shared.cfg.pipeline.lookahead
                    ),
                );
            }
        }
        let mixed = plan.precision.is_mixed();
        let refine_opts = RefineOptions {
            tol: ticket.slo.numeric.map(|p| p.tol()).unwrap_or(DEFAULT_REFINE_TOL),
            max_iters: DEFAULT_REFINE_CAP,
        };
        let pred = Predictor {
            model: shared.cfg.model.clone(),
            topo: shared.node.topology().clone(),
            dtype: S::DTYPE,
        };
        if mixed && trace.0 != 0 {
            let full_ns = secs_to_ns(pred.dist_makespan(
                self.routine.name(),
                self.a.rows(),
                self.rhs.as_ref().map(|b| b.cols()).unwrap_or(0),
                shared.cfg.tile,
                plan.grid.0,
                plan.grid.1,
            ));
            tracer.decision(
                trace,
                t0_ns,
                "mixed-route",
                format!(
                    "precision={} est_ns={} full_ns={} win_ns={}",
                    plan.precision.name(),
                    plan.est_ns,
                    full_ns,
                    full_ns.saturating_sub(plan.est_ns)
                ),
            );
        }
        // Factor-cache probe: a resident L staged over exactly this
        // live set lets the solve skip both the staging fan-out and
        // the factorization — rank 0 re-opens the stored handles and
        // runs only the triangular tail on the resident shards. syevd
        // shares no potrf prefix, so it bypasses the cache. A mixed
        // solve factors in the working dtype: its entries are keyed on
        // that dtype so a full factor of the same bytes never aliases.
        let cache_key = if shared.cfg.factor_cache && self.routine != DistRoutine::Syevd {
            let mut key = FactorKey::of(self.a.as_ref(), shared.cfg.tile, plan.grid);
            if let Precision::Mixed(w) = plan.precision {
                key.dtype = w;
            }
            Some(key)
        } else {
            None
        };
        let mut cached: Option<MpmdFactor> = None;
        if let Some(key) = &cache_key {
            cached = shared.probe_factor(key, live);
            if cached.is_some() {
                metrics.add_cache_hit();
            } else {
                metrics.add_cache_miss();
            }
        }
        let cache_hit = cached.is_some();
        let recompute_ns = match &cache_key {
            Some(key) if mixed => {
                secs_to_ns(pred.potrf2d_mixed(key.n, key.tile, key.grid.0, key.grid.1))
            }
            Some(key) => pred.recompute_ns(key.n, key.tile, key.grid.0, key.grid.1),
            None => 0,
        };
        if trace.0 != 0 {
            if let Some(key) = &cache_key {
                if cache_hit {
                    tracer.decision(
                        trace,
                        t0_ns,
                        "cache-hit",
                        format!("resident factor skips {recompute_ns} ns of staging+potrf"),
                    );
                } else {
                    tracer.decision(
                        trace,
                        t0_ns,
                        "cache-miss",
                        format!("n={} grid={}x{}", key.n, key.grid.0, key.grid.1),
                    );
                }
            }
        }
        let mut opened: Vec<IpcHandle> = Vec::new();
        // (`StagedShard` is not `Clone`, hence no `vec![None; n]`.)
        let mut staged: Vec<Option<StagedShard>> = (0..live.len()).map(|_| None).collect();
        // Set when a mixed attempt fell back to full precision: the
        // staged working-dtype shards must not seed the cache then.
        let fell_back = std::cell::Cell::new(false);
        let attempt = (|| -> Result<DistOut<S>> {
            let n = self.a.rows();
            let ndev = live.len();
            // Degraded mode runs on a subset view that shares the live
            // devices' VRAM/clocks but excludes the dead ones. The
            // planned layout — 1D or a P×Q grid — spans exactly the
            // live set; workers stage (and IPC-export) its 1D panels
            // or 2D tile shards alike through `build_panel`.
            let sub = shared.node.subset(live)?;
            let kind = plan.kind;

            // 1. Every live worker stages its own shard in its own
            // process and ships a pointer (rank 0) or handle (others) —
            // unless the factor is already resident, in which case the
            // cached shards (still owned by the workers' staged
            // ledgers; nothing below may free them) stand in and no
            // upload happens at all.
            if let Some(fac) = &cached {
                for (i, &ptr) in fac.ptrs.iter().enumerate() {
                    staged[i] = Some(StagedShard { ptr, handle: fac.handles[i] });
                }
            } else {
                // Mixed plans demote once on the host; every staged
                // shard (and its cudaIpc traffic) then moves
                // working-dtype bytes — half the fan-out volume.
                let aw: Option<Arc<Matrix<<S as MixedCapable>::Working>>> =
                    if mixed { Some(Arc::new(S::demote_host(self.a.as_ref())?)) } else { None };
                let (tx, rx) = mpsc::channel::<(usize, Result<StagedShard>)>();
                for (i, &dev) in live.iter().enumerate() {
                    let tx = tx.clone();
                    let a = self.a.clone();
                    let aw = aw.clone();
                    let sub = sub.clone();
                    let job: WorkerJob = Box::new(move |ctx| {
                        if !ctx.alive() {
                            // Dead process: dropping `tx` is the disconnect
                            // rank 0 observes.
                            return;
                        }
                        let res = match &aw {
                            Some(aw) => {
                                stage_shard::<<S as MixedCapable>::Working>(
                                    ctx, &sub, i, kind, aw, caller,
                                )
                            }
                            None => stage_shard::<S>(ctx, &sub, i, kind, &a, caller),
                        };
                        let _ = tx.send((i, res));
                    });
                    // A closed mailbox drops the job (and its `tx`): the
                    // missing reply is detected below.
                    let _ = shared.workers[dev].send(job);
                }
                drop(tx);

                // Drain EVERY reply before acting on errors: a successfully
                // staged shard must land in `staged` so the teardown below
                // can hand it back to its worker even when a sibling failed.
                let mut stage_err: Option<Error> = None;
                for (i, res) in rx {
                    match res {
                        Ok(sh) => staged[i] = Some(sh),
                        Err(e) => {
                            if stage_err.is_none() {
                                stage_err = Some(e);
                            }
                        }
                    }
                }
                if let Some(e) = stage_err {
                    return Err(e);
                }
            }

            // 2. Rank 0 opens every foreign handle in its own space,
            // paying the modeled cudaIpc round-trip per handle — the
            // exact terms `Predictor::mpmd_overhead` projects.
            let per_handle = shared.cfg.model.ipc_export_s
                + shared.cfg.model.ipc_open_s
                + shared.node.topology().h2d_time(64);
            let mut panels = Vec::with_capacity(ndev);
            for (i, sh) in staged.iter().enumerate() {
                let sh = sh.as_ref().ok_or_else(|| {
                    Error::ipc(format!("worker {} died before publishing its shard", live[i]))
                })?;
                match sh.handle {
                    Some(h) => {
                        let ptr = shared.registry.open(caller, h)?;
                        opened.push(h);
                        metrics.add_ipc_open();
                        // The caller's process runs next to device 0.
                        let dev0 = shared.node.device(0)?;
                        let o0 = dev0.clock().now_ns();
                        dev0.clock().advance(per_handle);
                        if trace.0 != 0 {
                            tracer.span(
                                trace,
                                self.root,
                                "ipc-open",
                                "xfer",
                                0,
                                "copy",
                                o0,
                                dev0.clock().now_ns(),
                                64,
                                0,
                            );
                        }
                        panels.push(ptr);
                    }
                    None => panels.push(sh.ptr),
                }
            }

            // 3. The single caller assembles the view and solves. A
            // mixed plan assembles the working-dtype view, factors and
            // solves narrow, and refines against the full-precision
            // A/b; a refinement stall or lost definiteness falls back
            // to a full-precision solve on the same subset — the
            // request never fails on precision grounds.
            if mixed {
                let b = self.rhs.as_ref().expect("validated at submit");
                let backend = SolverBackend::<<S as MixedCapable>::Working>::Native;
                let ctx =
                    Ctx::with_pipeline(&sub, &shared.cfg.model, &backend, shared.cfg.pipeline)
                        .with_trace(self.trace, self.root);
                let mut dm = DistMatrix::<<S as MixedCapable>::Working>::from_panels(
                    &sub, n, kind, panels,
                )?;
                let solved = (|| -> Result<Matrix<S>> {
                    if !cache_hit {
                        potrf_dist(&ctx, &mut dm)?;
                    }
                    let mrun = MixedRun {
                        node: &sub,
                        model: &shared.cfg.model,
                        pipeline: shared.cfg.pipeline,
                        layout: kind,
                        trace: (self.trace, self.root),
                        preempt: None,
                    };
                    S::mixed_refine(&mrun, &dm, &self.a, b, refine_opts, !cache_hit)
                        .map(|(x, _)| x)
                })();
                if trace.0 != 0 {
                    if let Some(snap) = ctx.timeline_snapshot() {
                        lift_timeline_spans(&tracer, trace, self.root, &snap);
                    }
                }
                // The workers (or the cache) own the panels.
                let _ = dm.into_panels();
                let why = match solved {
                    Ok(x) => return Ok(DistOut::Mat(x)),
                    Err(Error::RefineStalled { iters, residual, tol }) => format!(
                        "refine stalled: iters={iters} residual={residual:.3e} tol={tol:.1e}"
                    ),
                    Err(Error::NotPositiveDefinite { minor }) => {
                        format!("demoted matrix lost definiteness at minor {minor}")
                    }
                    Err(e) => return Err(e),
                };
                fell_back.set(true);
                metrics.add_mixed_fallback();
                if trace.0 != 0 {
                    tracer.decision(trace, shared.sim_now_ns(), "mixed-fallback", why);
                }
                // Typed fallback: rank 0 recovers at full precision on
                // the same live subset; the staged working shards are
                // torn down by the common teardown and never cached.
                let backend = SolverBackend::<S>::Native;
                let ctx =
                    Ctx::with_pipeline(&sub, &shared.cfg.model, &backend, shared.cfg.pipeline)
                        .with_trace(self.trace, self.root);
                let mut dmf = DistMatrix::<S>::scatter(&sub, &self.a, kind)?;
                potrf_dist(&ctx, &mut dmf)?;
                let x = potrs_dist(&ctx, &dmf, b)?;
                dmf.free()?;
                return Ok(DistOut::Mat(x));
            }
            let backend = SolverBackend::<S>::Native;
            let ctx = Ctx::with_pipeline(&sub, &shared.cfg.model, &backend, shared.cfg.pipeline)
                .with_trace(self.trace, self.root);
            let mut dm = DistMatrix::<S>::from_panels(&sub, n, kind, panels)?;
            let solved = (|| -> Result<DistOut<S>> {
                // syevd runs on A directly — only the Cholesky family
                // factors first (parity with `SolveService::submit_syevd`
                // and the `JaxMg::syevd` entry point).
                if self.routine == DistRoutine::Syevd {
                    let vals = syevd_dist(&ctx, &mut dm)?;
                    return Ok(DistOut::Eig(vals, dm.gather()?));
                }
                // The resident shards already hold L — the hit runs
                // only the triangular tail, bit-for-bit what the cold
                // path would compute from the same factor.
                if !cache_hit {
                    potrf_dist(&ctx, &mut dm)?;
                }
                match self.routine {
                    DistRoutine::Potrf => Ok(DistOut::Mat(dm.gather()?)),
                    DistRoutine::Potrs => {
                        let b = self.rhs.as_ref().expect("validated at submit");
                        Ok(DistOut::Mat(potrs_dist(&ctx, &dm, b)?))
                    }
                    DistRoutine::Potri => {
                        if cache_hit {
                            // potri destroys L in place — run it on a
                            // scatter round-trip copy so the resident
                            // factor survives the hit unchanged.
                            let l = dm.gather()?;
                            let mut copy = DistMatrix::<S>::scatter(&sub, &l, kind)?;
                            potri_dist(&ctx, &mut copy)?;
                            Ok(DistOut::Mat(copy.gather()?))
                        } else {
                            potri_dist(&ctx, &mut dm)?;
                            Ok(DistOut::Mat(dm.gather()?))
                        }
                    }
                    DistRoutine::Syevd => unreachable!("handled above"),
                }
            })();
            // Lookahead schedules issue panel/copy work directly onto
            // their streams, bypassing the per-charge span helpers —
            // lift the stream horizons into summary stage spans.
            if trace.0 != 0 {
                if let Some(snap) = ctx.timeline_snapshot() {
                    lift_timeline_spans(&tracer, trace, self.root, &snap);
                }
            }
            // The workers own the panels — never free them here.
            let _ = dm.into_panels();
            solved
        });
        // A router thread must survive anything a degraded solve can
        // throw (a killed worker's shards vanish mid-read): contain
        // unwinds here so teardown and in-flight accounting always run.
        let result: Result<DistOut<S>> =
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(attempt)) {
                Ok(r) => r,
                Err(p) => {
                    Err(Error::solver(format!("mpmd solve panicked: {}", panic_message(p))))
                }
            };

        // Keep a cold success resident: potrf left L in the staged
        // shards in place, so residency costs nothing to create — the
        // shards stay in the workers' ledgers and their bytes move
        // from this solve's reservation to the cache's resident
        // charge (the footprint always covers at least one matrix
        // copy per device, so the difference released below is
        // non-negative). potri destroyed L in place, so it never
        // seeds the cache.
        let mut kept: Option<Vec<usize>> = None;
        if result.is_ok() && !cache_hit && !fell_back.get() && self.routine != DistRoutine::Potri {
            if let Some(key) = &cache_key {
                let mut ptrs = Vec::with_capacity(live.len());
                let mut handles = Vec::with_capacity(live.len());
                for sh in staged.iter().flatten() {
                    ptrs.push(sh.ptr);
                    handles.push(sh.handle);
                }
                if ptrs.len() == live.len() {
                    let fac = MpmdFactor { devices: live.to_vec(), ptrs, handles };
                    kept = shared.insert_factor(*key, plan.kind, fac, recompute_ns);
                }
            }
        }

        // 4. Teardown on every path: close the caller's mappings, tear
        // down staged shards (revoke-on-free), release reservations.
        // Resident shards — a hit's source or a kept insert — stay
        // staged; a kept insert's factor bytes stay reserved under the
        // cache's name.
        for h in &opened {
            if shared.registry.close(caller, *h).is_ok() {
                metrics.add_ipc_close();
            }
        }
        for (i, &dev) in live.iter().enumerate() {
            let wctx = &shared.workers[dev].ctx;
            if !cache_hit && kept.is_none() {
                if let Some(sh) = &staged[i] {
                    wctx.release_staged(sh.ptr);
                }
            }
            let retained = kept.as_ref().map(|r| r[i]).unwrap_or(0);
            wctx.admission.release(fp.bytes(i).saturating_sub(retained));
        }
        if let (true, Some(key)) = (cache_hit, &cache_key) {
            shared.unpin_factor(key);
        }
        shared.quotas.release(ticket.slo.tenant, fp.as_slice().iter().sum());
        shared.front.notify();

        match result {
            Ok(out) => {
                let end_ns = shared.sim_now_ns();
                let exec_ns = end_ns.saturating_sub(t0_ns);
                note_completion(&shared.node, &shared.cfg.sched, ticket, queue_wait_ns, exec_ns);
                if trace.0 != 0 {
                    tracer.span(
                        trace, self.root, "exec", "exec", 0, "requests", t0_ns, end_ns, 0, 0,
                    );
                    tracer.close_root(
                        trace,
                        self.root,
                        &format!("request:{}", self.routine.name()),
                        0,
                        ticket.enq_ns,
                        end_ns,
                        0,
                        0,
                    );
                }
                // Feed the drift monitor: model estimate (this plan's
                // Predictor makespan), the estimate the queue actually
                // ranked with (post-correction, post-cache-deduction),
                // and the observed makespan. Cache hits run a different
                // program than the estimate models, so they stay out.
                if !cache_hit && (tracer.enabled() || shared.cfg.drift_correction) {
                    tracer.drift().record(
                        DriftKey {
                            routine: self.routine.name().to_string(),
                            dtype: S::DTYPE.name().to_string(),
                            n: self.a.rows() as u64,
                            grid: (plan.grid.0 as u32, plan.grid.1 as u32),
                        },
                        plan.est_ns,
                        ticket.est_ns,
                        exec_ns,
                    );
                }
                let stats = SolveStats {
                    queue_wait_ns,
                    exec_ns,
                    batch_size: 1,
                    coalesce_wait_ns: 0,
                    grid: plan.grid,
                    cache_hit,
                    fused_stages: 1,
                };
                self.publish_ok(out, stats);
                ExecResult::Published
            }
            Err(e) => {
                let dead: Vec<usize> =
                    live.iter().copied().filter(|&d| !shared.workers[d].alive()).collect();
                if dead.is_empty() {
                    // Terminal failure: counts as a completion, exactly
                    // like a failed solve on the SPMD front.
                    let end_ns = shared.sim_now_ns();
                    let exec_ns = end_ns.saturating_sub(t0_ns);
                    note_completion(
                        &shared.node,
                        &shared.cfg.sched,
                        ticket,
                        queue_wait_ns,
                        exec_ns,
                    );
                    if trace.0 != 0 {
                        tracer.close_root(
                            trace,
                            self.root,
                            &format!("request:{}:failed", self.routine.name()),
                            0,
                            ticket.enq_ns,
                            end_ns,
                            0,
                            0,
                        );
                    }
                    self.fail(ServeError::Failed(format!(
                        "mpmd {} failed: {e}",
                        self.routine.name()
                    )));
                    ExecResult::Published
                } else {
                    // A dead participant invalidates every factor
                    // staged on it (panic deaths never pass through
                    // `kill_worker`, so this is the only hook). The
                    // retry re-plans over the shrunk live set and runs
                    // cold — no request is lost to a stale hit.
                    for &d in &dead {
                        shared.invalidate_factors_on(d);
                    }
                    if trace.0 != 0 {
                        tracer.decision(
                            trace,
                            shared.sim_now_ns(),
                            "requeue",
                            format!("worker(s) {dead:?} died mid-solve; retry on live set"),
                        );
                    }
                    ExecResult::Requeue(dead)
                }
            }
        }
    }

    fn fail(&self, err: ServeError) {
        match &self.slot {
            DistSlot::Mat(slot) => publish_one(slot, Err(err)),
            DistSlot::Eig(slot) => publish_one(slot, Err(err)),
        }
    }

    fn ids(&self) -> (TraceId, SpanId) {
        (self.trace, self.root)
    }
}

// ---------------------------------------------------------------------------
// Pinned pod requests (the coalesced small-solve path)
// ---------------------------------------------------------------------------

struct PodReq<S: Scalar> {
    routine: SmallRoutine,
    systems: Vec<Matrix<S>>,
    rhss: Vec<Option<Matrix<S>>>,
    slots: Vec<Slot<Matrix<S>>>,
    waits: Vec<u64>,
    /// Trace identity, minted in the pod builder (nulls when tracing
    /// is off). A dead-worker re-route keeps this identity; the
    /// unpublished *tail* of a degraded rerun becomes a fresh pod and
    /// mints a fresh trace (the original root closed with the pod that
    /// spawned it), linked by a "requeue" decision.
    trace: TraceId,
    root: SpanId,
}

impl<S: Scalar> PodWork for PodReq<S> {
    fn bytes(&self) -> usize {
        // The pod is pinned to one device, so its reservation is the
        // whole-bucket arena: `Footprint::for_pod` over a single
        // "device" — one sizing formula for both fronts.
        let dims: Vec<(usize, usize)> = self
            .systems
            .iter()
            .zip(&self.rhss)
            .map(|(a, b)| (a.rows(), b.as_ref().map(|m| m.cols()).unwrap_or(0)))
            .collect();
        Footprint::for_pod(self.routine.name(), &dims, 1, S::DTYPE)
            .expect("SmallRoutine names are known to the workspace model")
            .bytes(0)
    }

    fn run(&self, ctx: &WorkerCtx, ticket: &SloTicket, sched: SchedConfig) -> PodOutcome {
        let t0_ns = ctx.node.sim_time_ns();
        let queue_wait_ns = t0_ns.saturating_sub(ticket.enq_ns);
        let occupancy = self.systems.len();
        let tracer = ctx.node.tracer().clone();
        let trace = self.trace;
        if trace.0 != 0 {
            tracer.span(
                trace,
                self.root,
                "queue-wait",
                "sched",
                ctx.device,
                "requests",
                ticket.enq_ns,
                t0_ns,
                0,
                0,
            );
        }
        let swept = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_bucket::<S>(
                self.routine,
                &ctx.node,
                &ctx.model,
                &self.systems,
                &self.rhss,
                Some(ctx.device),
            )
        }));
        match swept {
            Ok(Ok((results, makespan_ns))) => {
                let exec_ns = ctx.node.sim_time_ns().saturating_sub(t0_ns);
                let total_wait: u64 = self.waits.iter().sum();
                ctx.node.metrics().add_batch_bucket(occupancy as u64, total_wait, makespan_ns);
                note_completion(&ctx.node, &sched, ticket, queue_wait_ns, exec_ns);
                if trace.0 != 0 {
                    let end_ns = t0_ns.saturating_add(exec_ns);
                    tracer.span(
                        trace,
                        self.root,
                        "exec",
                        "exec",
                        ctx.device,
                        "requests",
                        t0_ns,
                        end_ns,
                        0,
                        0,
                    );
                    tracer.close_root(
                        trace,
                        self.root,
                        &format!("request:pod:{}", self.routine.name()),
                        ctx.device,
                        ticket.enq_ns,
                        end_ns,
                        0,
                        0,
                    );
                }
                for ((slot, x), wait_ns) in
                    self.slots.iter().zip(results).zip(self.waits.iter().copied())
                {
                    let stats = SolveStats {
                        queue_wait_ns,
                        exec_ns,
                        batch_size: occupancy,
                        coalesce_wait_ns: wait_ns,
                        grid: (1, 1),
                        cache_hit: false,
                        fused_stages: 1,
                    };
                    publish_one(slot, Ok((x, stats)));
                }
                PodOutcome::Published
            }
            _ => {
                if !ctx.alive() {
                    return PodOutcome::WorkerDead;
                }
                // A sweep aborts at its first failing system; rerun one
                // at a time, pinned to this device, so only the
                // culprit's waiter sees the failure. If the process
                // dies mid-loop, the unpublished tail re-enters the
                // frontend queue as a fresh pod on the other devices.
                for i in 0..occupancy {
                    if !ctx.alive() {
                        // The tail is a *new* submission: it mints its
                        // own trace (this pod's root closes below with
                        // the members already resolved) and the link
                        // between the two trees is the decision record.
                        let (tail_trace, tail_root) = tracer.new_trace();
                        let tail = PodReq::<S> {
                            routine: self.routine,
                            systems: self.systems[i..].to_vec(),
                            rhss: self.rhss[i..].to_vec(),
                            slots: self.slots[i..].to_vec(),
                            waits: self.waits[i..].to_vec(),
                            trace: tail_trace,
                            root: tail_root,
                        };
                        ctx.node.metrics().add_mpmd_requeue();
                        if tracer.enabled() {
                            tracer.decision(
                                trace,
                                ctx.node.sim_time_ns(),
                                "requeue",
                                format!(
                                    "worker {} died mid-rerun; {} solve(s) re-enter as trace {}",
                                    ctx.device,
                                    occupancy - i,
                                    tail_trace.0
                                ),
                            );
                        }
                        let mut work =
                            QueuedWork::fresh(WorkKind::Pod(Arc::new(tail)), ticket.slo, 0);
                        work.excluded.push(ctx.device);
                        if let Err(w) = ctx.front.enqueue(work, ctx.node.sim_time_ns()) {
                            fail_work(
                                w,
                                ServeError::Failed(
                                    "mpmd service shut down during retry".to_string(),
                                ),
                                &tracer,
                                ctx.node.sim_time_ns(),
                            );
                        } else {
                            ctx.node.metrics().add_service_submission();
                        }
                        break;
                    }
                    let one = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_bucket::<S>(
                            self.routine,
                            &ctx.node,
                            &ctx.model,
                            &self.systems[i..i + 1],
                            &self.rhss[i..i + 1],
                            Some(ctx.device),
                        )
                    }));
                    let exec_ns = ctx.node.sim_time_ns().saturating_sub(t0_ns);
                    let outcome = match one {
                        Ok(Ok((mut v, _))) => Ok((
                            v.pop().expect("batch of one"),
                            SolveStats {
                                queue_wait_ns,
                                exec_ns,
                                batch_size: 1,
                                coalesce_wait_ns: self.waits[i],
                                grid: (1, 1),
                                cache_hit: false,
                                fused_stages: 1,
                            },
                        )),
                        Ok(Err(e)) => Err(ServeError::Failed(format!("small solve failed: {e}"))),
                        Err(p) => Err(ServeError::Failed(panic_message(p))),
                    };
                    publish_one(&self.slots[i], outcome);
                }
                // One admitted pod, one completion — whichever path
                // resolved it (parity with the SPMD bucket flusher).
                let exec_ns = ctx.node.sim_time_ns().saturating_sub(t0_ns);
                note_completion(&ctx.node, &sched, ticket, queue_wait_ns, exec_ns);
                if trace.0 != 0 {
                    let end_ns = t0_ns.saturating_add(exec_ns);
                    tracer.span(
                        trace,
                        self.root,
                        "exec",
                        "exec",
                        ctx.device,
                        "requests",
                        t0_ns,
                        end_ns,
                        0,
                        0,
                    );
                    tracer.close_root(
                        trace,
                        self.root,
                        &format!("request:pod:{}", self.routine.name()),
                        ctx.device,
                        ticket.enq_ns,
                        end_ns,
                        0,
                        0,
                    );
                }
                PodOutcome::Published
            }
        }
    }

    fn fail(&self, err: ServeError) {
        publish_error(&self.slots, err);
    }

    fn ids(&self) -> (TraceId, SpanId) {
        (self.trace, self.root)
    }
}

// ---------------------------------------------------------------------------
// Dispatcher
// ---------------------------------------------------------------------------

fn reserve_all(shared: &Shared, live: &[usize], fp: &Footprint) -> bool {
    for (i, &dev) in live.iter().enumerate() {
        if shared.workers[dev].ctx.admission.try_reserve(fp.bytes(i)).is_err() {
            for (j, &dj) in live.iter().enumerate().take(i) {
                shared.workers[dj].ctx.admission.release(fp.bytes(j));
            }
            return false;
        }
    }
    true
}

/// Fold the workers' current reservations into per-island sums and
/// record the fabric high-water marks
/// ([`crate::metrics::Metrics::note_island_admitted`]) — the MPMD
/// half of per-island admission accounting. No-op on a flat node.
fn note_island_reserved(shared: &Shared) {
    let topo = shared.node.topology();
    if topo.num_islands() <= 1 {
        return;
    }
    let mut sums = [0u64; 8];
    for (d, w) in shared.workers.iter().enumerate() {
        sums[topo.island_of(d).min(sums.len() - 1)] += w.ctx.admission.reserved() as u64;
    }
    let m = shared.node.metrics();
    for (i, &s) in sums.iter().enumerate() {
        if s > 0 {
            m.note_island_admitted(i, s);
        }
    }
}

/// Route one popped work item. Returns `false` when the pick could not
/// be admitted yet (it is restored under its original ticket; the
/// dispatcher waits for a release before retrying — the queue's skip
/// aging preserves the no-starvation guarantee under either policy).
fn dispatch(
    shared: &Arc<Shared>,
    routers: &Arc<JobQueue>,
    ticket: SloTicket,
    work: QueuedWork,
) -> bool {
    let live = shared.live_workers(&work.excluded);
    let metrics = shared.node.metrics().clone();
    if live.is_empty() {
        // Typed terminal failure: re-queueing against an empty live
        // set would loop forever (nothing can ever admit the work).
        fail_work(
            work,
            ServeError::NoLiveWorkers { total: shared.workers.len() },
            shared.node.tracer(),
            shared.sim_now_ns(),
        );
        shared.front.complete();
        return true;
    }
    // Clone the routed payload out first so `work` can move into the
    // execution closures below.
    enum Routed {
        Dist(Arc<dyn DistWork>),
        Pod(Arc<dyn PodWork>),
    }
    let routed = match &work.kind {
        WorkKind::Dist(req) => Routed::Dist(req.clone()),
        WorkKind::Pod(pod) => Routed::Pod(pod.clone()),
    };
    match routed {
        Routed::Dist(req) => {
            // Plan over the live set: the selector (or the configured
            // override) picks the grid shape, and admission is against
            // the exact per-device shards of the planned layout.
            let plan = match req.plan(shared, live.len()) {
                Ok(plan) => plan,
                Err(e) => {
                    let (trace, root) = req.ids();
                    close_failed_root(shared.node.tracer(), trace, root, shared.sim_now_ns());
                    req.fail(ServeError::Failed(format!("solve planning failed: {e}")));
                    shared.front.complete();
                    return true;
                }
            };
            // Fail fast when a live device could never hold its share —
            // waiting for releases would deadlock the queue head.
            for (i, &dev) in live.iter().enumerate() {
                if plan.footprint.bytes(i) > shared.workers[dev].ctx.admission.capacity() {
                    let (trace, root) = req.ids();
                    close_failed_root(shared.node.tracer(), trace, root, shared.sim_now_ns());
                    req.fail(ServeError::Failed(format!(
                        "declared footprint ({} B) exceeds device {dev}'s capacity",
                        plan.footprint.bytes(i)
                    )));
                    shared.front.complete();
                    return true;
                }
            }
            // Resident factors yield to admission pressure: each
            // eviction frees the lowest-value entry's bytes, so the
            // retry loop terminates when the cache runs dry.
            let mut admitted = reserve_all(shared, &live, &plan.footprint);
            while !admitted && shared.evict_factor() {
                admitted = reserve_all(shared, &live, &plan.footprint);
            }
            if !admitted {
                let mut st = shared.front.state.lock().unwrap();
                st.queue.restore(ticket, work);
                st.in_flight -= 1;
                return false;
            }
            // Tenant quota: admitted footprint summed over the live
            // set, the same accountant the SPMD front charges.
            let fp_total: usize = plan.footprint.as_slice().iter().sum();
            if !shared.quotas.would_admit(ticket.slo.tenant, fp_total) {
                for (i, &dev) in live.iter().enumerate() {
                    shared.workers[dev].ctx.admission.release(plan.footprint.bytes(i));
                }
                let mut st = shared.front.state.lock().unwrap();
                st.queue.restore(ticket, work);
                st.in_flight -= 1;
                return false;
            }
            shared.quotas.admit(ticket.slo.tenant, fp_total);
            note_island_reserved(shared);
            metrics.add_mpmd_routed(shared.sim_now_ns().saturating_sub(ticket.enq_ns));
            let tr = shared.node.tracer();
            if tr.enabled() {
                let (trace, _) = req.ids();
                tr.decision(
                    trace,
                    shared.sim_now_ns(),
                    "admit",
                    format!(
                        "dist grid={}x{} live={} est_ns={}",
                        plan.grid.0,
                        plan.grid.1,
                        live.len(),
                        ticket.est_ns
                    ),
                );
            }
            let shared2 = shared.clone();
            let _ = routers.submit(move || {
                match req.execute(&shared2, &live, &plan, &ticket) {
                    ExecResult::Published => shared2.front.complete(),
                    ExecResult::Requeue(dead) => {
                        shared2.node.metrics().add_mpmd_requeue();
                        shared2.front.requeue(ticket, work, &dead);
                    }
                }
            });
            true
        }
        Routed::Pod(pod) => {
            let bytes = pod.bytes();
            let mut cands: Vec<usize> = live
                .iter()
                .copied()
                .filter(|&d| bytes <= shared.workers[d].ctx.admission.capacity())
                .collect();
            if cands.is_empty() {
                let (trace, root) = pod.ids();
                close_failed_root(shared.node.tracer(), trace, root, shared.sim_now_ns());
                pod.fail(ServeError::Failed(format!(
                    "pod of {bytes} B exceeds every live device's capacity"
                )));
                shared.front.complete();
                return true;
            }
            // Pin to the least-loaded live worker that admits the pod.
            // Resident factors yield here too: a device filled with
            // cached factors must not starve the small-solve path.
            cands.sort_by_key(|&d| (shared.workers[d].queue_depth(), d));
            let mut target = None;
            'admit: loop {
                for &d in &cands {
                    if shared.workers[d].ctx.admission.try_reserve(bytes).is_ok() {
                        target = Some(d);
                        break 'admit;
                    }
                }
                if !shared.evict_factor() {
                    break;
                }
            }
            let Some(dev) = target else {
                let mut st = shared.front.state.lock().unwrap();
                st.queue.restore(ticket, work);
                st.in_flight -= 1;
                return false;
            };
            if !shared.quotas.would_admit(ticket.slo.tenant, bytes) {
                shared.workers[dev].ctx.admission.release(bytes);
                let mut st = shared.front.state.lock().unwrap();
                st.queue.restore(ticket, work);
                st.in_flight -= 1;
                return false;
            }
            shared.quotas.admit(ticket.slo.tenant, bytes);
            note_island_reserved(shared);
            metrics.add_mpmd_routed(shared.sim_now_ns().saturating_sub(ticket.enq_ns));
            let tr = shared.node.tracer();
            if tr.enabled() {
                let (trace, _) = pod.ids();
                tr.decision(
                    trace,
                    shared.sim_now_ns(),
                    "admit",
                    format!("pod pinned to worker {dev} bytes={bytes}"),
                );
            }
            let shared2 = shared.clone();
            let sched = shared.cfg.sched;
            let job: WorkerJob = Box::new(move |ctx| {
                let note_requeue = |ctx: &WorkerCtx| {
                    let tr = ctx.node.tracer();
                    if tr.enabled() {
                        let (trace, _) = pod.ids();
                        tr.decision(
                            trace,
                            ctx.node.sim_time_ns(),
                            "requeue",
                            format!("worker {} dead; pod re-routed", ctx.device),
                        );
                    }
                };
                if !ctx.alive() {
                    // Draining a dead worker: hand the pod back.
                    ctx.admission.release(bytes);
                    shared2.quotas.release(ticket.slo.tenant, bytes);
                    ctx.node.metrics().add_mpmd_requeue();
                    note_requeue(ctx);
                    ctx.front.requeue(ticket, work, &[ctx.device]);
                    return;
                }
                match pod.run(ctx, &ticket, sched) {
                    PodOutcome::Published => {
                        ctx.admission.release(bytes);
                        shared2.quotas.release(ticket.slo.tenant, bytes);
                        ctx.front.complete();
                    }
                    PodOutcome::WorkerDead => {
                        ctx.admission.release(bytes);
                        shared2.quotas.release(ticket.slo.tenant, bytes);
                        ctx.node.metrics().add_mpmd_requeue();
                        note_requeue(ctx);
                        ctx.front.requeue(ticket, work, &[ctx.device]);
                    }
                }
            });
            if let Err(job) = shared.workers[dev].send(job) {
                // Raced a death between admission and send: run the job
                // in dead mode right here — it releases the reservation
                // and re-queues the pod with this device excluded.
                job(&shared.workers[dev].ctx);
            }
            true
        }
    }
}

fn dispatcher_loop(shared: Arc<Shared>, small: Arc<Mutex<MpmdSmall>>, routers: Arc<JobQueue>) {
    // Idle poll cadence derived from the wall-dwell bound through
    // `flusher_tick`, whose floor clamp keeps a zero-dwell policy
    // polling instead of busy-spinning (the SPMD flusher's fix, shared).
    let tick = flusher_tick(shared.cfg.policy.max_wall_dwell);
    loop {
        // Frontend-driven coalescer tick: dwell-expired buckets flush
        // even when no further submit arrives (the serve-loop twin of
        // the SPMD service's background flusher thread).
        flush_due_buckets(&shared, &small);
        let popped = {
            let mut st = shared.front.state.lock().unwrap();
            if st.shutdown && st.queue.is_empty() && st.in_flight == 0 {
                return;
            }
            match st.queue.pop_next() {
                Some((ticket, w)) => {
                    st.in_flight += 1;
                    Some((ticket, w))
                }
                None => {
                    let _unused = shared.front.cv.wait_timeout(st, tick).unwrap();
                    None
                }
            }
        };
        let Some((ticket, work)) = popped else { continue };
        if !dispatch(&shared, &routers, ticket, work) {
            // Head-of-line wait: capacity frees when something
            // completes; the release paths notify this condvar.
            let st = shared.front.state.lock().unwrap();
            let _unused = shared.front.cv.wait_timeout(st, Duration::from_millis(5)).unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// Small-solve coalescing state
// ---------------------------------------------------------------------------

/// One queued small request, type-erased so one planner holds every
/// dtype (the builder installed by the first push downcasts back).
type SmallPayload = Box<dyn Any + Send>;

/// Turns a flushed bucket + its payloads into a routable pod.
type PodBuilder = dyn Fn(FlushedBucket, Vec<SmallPayload>) -> QueuedWork + Send + Sync;

struct MpmdSmallJob<S: Scalar> {
    a: Matrix<S>,
    rhs: Option<Matrix<S>>,
    slot: Slot<Matrix<S>>,
    slo: Slo,
}

struct MpmdSmall {
    planner: BatchPlanner,
    payloads: HashMap<u64, SmallPayload>,
    builders: HashMap<BucketKey, Arc<PodBuilder>>,
    /// Memoized `Predictor::batched_wins` per (routine, dtype, class).
    decisions: HashMap<(SmallRoutine, DType, u32), bool>,
}

fn pod_builder<S: Scalar>(routine: SmallRoutine, tracer: Arc<Tracer>) -> Arc<PodBuilder> {
    Arc::new(move |bucket: FlushedBucket, payloads: Vec<SmallPayload>| {
        let mut systems = Vec::with_capacity(payloads.len());
        let mut rhss = Vec::with_capacity(payloads.len());
        let mut slots = Vec::with_capacity(payloads.len());
        // The pod inherits the strictest SLO of its members: the most
        // latency-sensitive class and the earliest deadline (same
        // aggregation as the SPMD small-flusher).
        let mut class: Option<SloClass> = None;
        let mut deadline: Option<u64> = None;
        for p in payloads {
            let job = *p.downcast::<MpmdSmallJob<S>>().expect("bucket key pins the dtype");
            class = Some(class.map_or(job.slo.class, |c| c.min(job.slo.class)));
            if let Some(d) = job.slo.deadline_ns {
                deadline = Some(deadline.map_or(d, |x| x.min(d)));
            }
            systems.push(job.a);
            rhss.push(job.rhs);
            slots.push(job.slot);
        }
        let pod_slo = Slo {
            class: class.unwrap_or(SloClass::Standard),
            deadline_ns: deadline,
            tenant: 0,
            numeric: None,
        };
        // One flushed bucket = one submission on the frontend queue =
        // one trace (mirrors the SPMD small-flusher's accounting).
        let (trace, root) = tracer.new_trace();
        QueuedWork::fresh(
            WorkKind::Pod(Arc::new(PodReq::<S> {
                routine,
                systems,
                rhss,
                slots,
                waits: bucket.waits_ns,
                trace,
                root,
            })),
            pod_slo,
            0,
        )
    })
}

fn collect_ready(st: &mut MpmdSmall, bucket: FlushedBucket, out: &mut Vec<QueuedWork>) {
    let builder = st.builders.get(&bucket.key).expect("builder installed on first push").clone();
    let payloads: Vec<SmallPayload> =
        bucket.ids.iter().map(|id| st.payloads.remove(id).expect("payload stored")).collect();
    out.push(builder(bucket, payloads));
}

fn flush_due_buckets(shared: &Shared, small: &Mutex<MpmdSmall>) {
    let now_ns = shared.sim_now_ns();
    let mut ready = Vec::new();
    {
        let mut st = small.lock().unwrap();
        for key in st.planner.due(now_ns) {
            if let Some(bucket) = st.planner.flush(key, now_ns) {
                collect_ready(&mut st, bucket, &mut ready);
            }
        }
    }
    for w in ready {
        if let Err(w) = shared.front.enqueue(w, now_ns) {
            fail_work(
                w,
                ServeError::Failed("mpmd service is shut down".to_string()),
                shared.node.tracer(),
                now_ns,
            );
        } else {
            shared.node.metrics().add_service_submission();
        }
    }
}

// ---------------------------------------------------------------------------
// The service
// ---------------------------------------------------------------------------

/// The MPMD serving subsystem: one simulated process per GPU behind a
/// rank-0 frontend (see the module docs and `crate::serve`).
pub struct MpmdService {
    shared: Arc<Shared>,
    small: Arc<Mutex<MpmdSmall>>,
    routers: Option<Arc<JobQueue>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    worker_threads: Vec<std::thread::JoinHandle<()>>,
}

impl MpmdService {
    /// Serve `node` with the default configuration.
    pub fn new(node: SimNode) -> Self {
        Self::with_config(node, MpmdConfig::default())
    }

    /// Serve `node`: spawns one worker process per device, the router
    /// pool, and the rank-0 dispatcher.
    pub fn with_config(node: SimNode, cfg: MpmdConfig) -> Self {
        let registry = Arc::new(IpcRegistry::new());
        let front = Arc::new(FrontShared::new(cfg.sched));
        let mut workers = Vec::new();
        let mut worker_threads = Vec::new();
        for d in 0..node.num_devices() {
            let ctx = WorkerCtx::new(
                d,
                node.clone(),
                registry.clone(),
                cfg.model.clone(),
                front.clone(),
            );
            let (link, thread) = spawn_worker(ctx);
            workers.push(link);
            worker_threads.push(thread);
        }
        let policy = cfg.policy;
        let routers_n = cfg.routers.max(1);
        let quotas = TenantQuotas::new(cfg.sched.tenant_quota);
        let shared = Arc::new(Shared {
            node,
            registry,
            cfg,
            workers,
            front,
            plans: GridPlanCache::new(),
            caller: AddressSpace(0),
            quotas,
            last_seen_ns: AtomicU64::new(0),
            cache: Mutex::new(FactorCache::new()),
        });
        let small = Arc::new(Mutex::new(MpmdSmall {
            planner: BatchPlanner::new(policy),
            payloads: HashMap::new(),
            builders: HashMap::new(),
            decisions: HashMap::new(),
        }));
        let routers = Arc::new(JobQueue::new(routers_n));
        let dispatcher = {
            let shared = shared.clone();
            let small = small.clone();
            let routers = routers.clone();
            std::thread::spawn(move || dispatcher_loop(shared, small, routers))
        };
        MpmdService {
            shared,
            small,
            routers: Some(routers),
            dispatcher: Some(dispatcher),
            worker_threads,
        }
    }

    fn enqueue_dist<S: Scalar + MixedCapable>(&self, mut req: DistReq<S>, slo: Slo) -> Result<()> {
        let tracer = self.shared.node.tracer();
        let (trace, root) = tracer.new_trace();
        req.trace = trace;
        req.root = root;
        // SJF/EDF ranks off the same Predictor makespan the planner
        // mints (estimated over the full worker set; a degraded-mode
        // dispatch re-plans, but the ticket keeps its submit-time
        // estimate). A failed estimate degrades to 0 — FIFO within
        // rank — rather than failing the submit. When the factor is
        // resident the potrf prefix is deducted: the ticket ranks by
        // the tail the hit will actually run. With drift correction on,
        // the estimate is further scaled by the observed/predicted
        // ratio the drift monitor accumulated for this key.
        let est_ns = match req.plan(&self.shared, self.shared.workers.len()) {
            Ok(p) => {
                let mut est = p.est_ns;
                if self.shared.cfg.factor_cache && req.routine != DistRoutine::Syevd {
                    // A mixed plan factors (and caches) in the working
                    // dtype — probe under that key and deduct the mixed
                    // prefix a hit would skip.
                    let mut key = FactorKey::of(req.a.as_ref(), self.shared.cfg.tile, p.grid);
                    let pred = Predictor {
                        model: self.shared.cfg.model.clone(),
                        topo: self.shared.node.topology().clone(),
                        dtype: S::DTYPE,
                    };
                    let re = match p.precision {
                        Precision::Mixed(w) => {
                            key.dtype = w;
                            secs_to_ns(pred.potrf2d_mixed(
                                key.n,
                                key.tile,
                                key.grid.0,
                                key.grid.1,
                            ))
                        }
                        Precision::Full => {
                            pred.recompute_ns(key.n, key.tile, key.grid.0, key.grid.1)
                        }
                    };
                    if self.shared.cache.lock().unwrap().contains(&key) {
                        est = est.saturating_sub(re);
                    }
                }
                if self.shared.cfg.drift_correction {
                    let key = DriftKey {
                        routine: req.routine.name().to_string(),
                        dtype: S::DTYPE.name().to_string(),
                        n: req.a.rows() as u64,
                        grid: (p.grid.0 as u32, p.grid.1 as u32),
                    };
                    est = tracer.drift().corrected_est(&key, est);
                }
                est
            }
            Err(_) => 0,
        };
        let work = QueuedWork::fresh(WorkKind::Dist(Arc::new(req)), slo, est_ns);
        if let Err(w) = self.shared.front.enqueue(work, self.shared.sim_now_ns()) {
            fail_work(
                w,
                ServeError::Failed("mpmd service is shut down".to_string()),
                tracer,
                self.shared.sim_now_ns(),
            );
            return Err(Error::config("mpmd service is shut down"));
        }
        self.shared.node.metrics().add_service_submission();
        Ok(())
    }

    fn validate_square<S: Scalar>(a: &Matrix<S>) -> Result<usize> {
        let n = a.require_square()?;
        if n == 0 {
            return Err(Error::shape("cannot solve an empty system"));
        }
        Ok(n)
    }

    /// Distributed Cholesky factor: returns the factored matrix.
    pub fn submit_potrf<S: Scalar + MixedCapable>(
        &self,
        a: Matrix<S>,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        self.submit_potrf_slo(a, Slo::standard())
    }

    /// [`Self::submit_potrf`] with an explicit SLO.
    pub fn submit_potrf_slo<S: Scalar + MixedCapable>(
        &self,
        a: Matrix<S>,
        slo: Slo,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        Self::validate_square(&a)?;
        let (handle, slot) = handle_pair::<Matrix<S>>();
        self.enqueue_dist(
            DistReq {
                routine: DistRoutine::Potrf,
                a: Arc::new(a),
                rhs: None,
                slot: DistSlot::Mat(slot),
                numeric: None,
                trace: TraceId(0),
                root: SpanId(0),
            },
            slo,
        )?;
        Ok(handle)
    }

    /// Distributed solve `A·X = B` (factor + two-sweep solve).
    pub fn submit_potrs<S: Scalar + MixedCapable>(
        &self,
        a: Matrix<S>,
        b: Matrix<S>,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        self.submit_potrs_slo(a, b, Slo::standard())
    }

    /// [`Self::submit_potrs`] with an explicit SLO. An
    /// [`Slo::with_tolerance`] policy routes the solve through the
    /// mixed tier when the cost model predicts a win; a refinement
    /// stall falls back to full precision — the request never fails
    /// on precision grounds.
    pub fn submit_potrs_slo<S: Scalar + MixedCapable>(
        &self,
        a: Matrix<S>,
        b: Matrix<S>,
        slo: Slo,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        let n = Self::validate_square(&a)?;
        if b.rows() != n {
            return Err(Error::shape(format!("rhs has {} rows, matrix is {n}x{n}", b.rows())));
        }
        let (handle, slot) = handle_pair::<Matrix<S>>();
        self.enqueue_dist(
            DistReq {
                routine: DistRoutine::Potrs,
                a: Arc::new(a),
                rhs: Some(b),
                slot: DistSlot::Mat(slot),
                numeric: slo.numeric,
                trace: TraceId(0),
                root: SpanId(0),
            },
            slo,
        )?;
        Ok(handle)
    }

    /// Distributed SPD/HPD inverse.
    pub fn submit_potri<S: Scalar + MixedCapable>(
        &self,
        a: Matrix<S>,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        self.submit_potri_slo(a, Slo::standard())
    }

    /// [`Self::submit_potri`] with an explicit SLO.
    pub fn submit_potri_slo<S: Scalar + MixedCapable>(
        &self,
        a: Matrix<S>,
        slo: Slo,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        Self::validate_square(&a)?;
        let (handle, slot) = handle_pair::<Matrix<S>>();
        self.enqueue_dist(
            DistReq {
                routine: DistRoutine::Potri,
                a: Arc::new(a),
                rhs: None,
                slot: DistSlot::Mat(slot),
                numeric: None,
                trace: TraceId(0),
                root: SpanId(0),
            },
            slo,
        )?;
        Ok(handle)
    }

    /// Distributed eigendecomposition: ascending eigenvalues +
    /// eigenvector columns.
    pub fn submit_syevd<S: Scalar + MixedCapable>(
        &self,
        a: Matrix<S>,
    ) -> Result<ServiceHandle<(Vec<S::Real>, Matrix<S>)>> {
        self.submit_syevd_slo(a, Slo::standard())
    }

    /// [`Self::submit_syevd`] with an explicit SLO.
    pub fn submit_syevd_slo<S: Scalar + MixedCapable>(
        &self,
        a: Matrix<S>,
        slo: Slo,
    ) -> Result<ServiceHandle<(Vec<S::Real>, Matrix<S>)>> {
        Self::validate_square(&a)?;
        let (handle, slot) = handle_pair::<(Vec<S::Real>, Matrix<S>)>();
        self.enqueue_dist(
            DistReq {
                routine: DistRoutine::Syevd,
                a: Arc::new(a),
                rhs: None,
                slot: DistSlot::Eig(slot),
                numeric: None,
                trace: TraceId(0),
                root: SpanId(0),
            },
            slo,
        )?;
        Ok(handle)
    }

    /// Submit a small solve: coalesced into a worker-pinned pod when
    /// the cost model says batching wins, routed distributed otherwise
    /// — the MPMD twin of `SolveService::submit_small`.
    pub fn submit_small<S: Scalar + MixedCapable>(
        &self,
        routine: SmallRoutine,
        a: Matrix<S>,
        rhs: Option<Matrix<S>>,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        self.submit_small_slo(routine, a, rhs, Slo::standard())
    }

    /// [`Self::submit_small`] with an explicit SLO. A coalesced pod
    /// inherits the strictest SLO among its members.
    pub fn submit_small_slo<S: Scalar + MixedCapable>(
        &self,
        routine: SmallRoutine,
        a: Matrix<S>,
        rhs: Option<Matrix<S>>,
        slo: Slo,
    ) -> Result<ServiceHandle<Matrix<S>>> {
        let n = Self::validate_square(&a)?;
        match (routine, &rhs) {
            (SmallRoutine::Potrs, None) => {
                return Err(Error::config("potrs needs a right-hand side"));
            }
            (SmallRoutine::Potrs, Some(b)) if b.rows() != n => {
                return Err(Error::shape(format!(
                    "rhs has {} rows, matrix is {n}x{n}",
                    b.rows()
                )));
            }
            (SmallRoutine::Potrf | SmallRoutine::Potri, Some(_)) => {
                return Err(Error::config("only potrs takes a right-hand side"));
            }
            _ => {}
        }
        // Capacity gate: a pinned pod concentrates the whole bucket on
        // ONE device (unlike the SPMD round-robin pod), so the
        // worst-case bucket is `max_batch` systems of this size-class
        // on a single device's VRAM.
        let nrhs = rhs.as_ref().map(|b| b.cols()).unwrap_or(1);
        let e = S::DTYPE.size_of();
        let class = size_class(n) as usize;
        let per_system = class * class * e
            + if matches!(routine, SmallRoutine::Potrs) { class * nrhs * e } else { 0 };
        let worst_bucket = self.shared.cfg.policy.max_batch * per_system;
        let max_cap = self
            .shared
            .workers
            .iter()
            .map(|w| w.ctx.admission.capacity())
            .max()
            .unwrap_or(0);
        let coalesce = worst_bucket <= max_cap
            && n <= self.shared.cfg.policy.small_dim
            && self.batched_decision::<S>(routine, class);
        if !coalesce {
            // The latency bound holds on every submit, whichever path
            // this request takes.
            self.flush_due_small();
            let dist = match routine {
                SmallRoutine::Potrf => DistRoutine::Potrf,
                SmallRoutine::Potrs => DistRoutine::Potrs,
                SmallRoutine::Potri => DistRoutine::Potri,
            };
            let (handle, slot) = handle_pair::<Matrix<S>>();
            self.enqueue_dist(
                DistReq {
                    routine: dist,
                    a: Arc::new(a),
                    rhs,
                    slot: DistSlot::Mat(slot),
                    numeric: if dist == DistRoutine::Potrs { slo.numeric } else { None },
                    trace: TraceId(0),
                    root: SpanId(0),
                },
                slo,
            )?;
            return Ok(handle);
        }

        let (handle, slot) = handle_pair::<Matrix<S>>();
        let key = BucketKey::new(routine, S::DTYPE, n);
        let now_ns = self.shared.sim_now_ns();
        let mut ready = Vec::new();
        {
            let mut st = self.small.lock().unwrap();
            st.builders
                .entry(key)
                .or_insert_with(|| pod_builder::<S>(routine, self.shared.node.tracer().clone()));
            let (id, flushed) = st.planner.push(key, now_ns);
            st.payloads.insert(id, Box::new(MpmdSmallJob::<S> { a, rhs, slot, slo }));
            if let Some(bucket) = flushed {
                collect_ready(&mut st, bucket, &mut ready);
            }
            for k in st.planner.due(now_ns) {
                if let Some(bucket) = st.planner.flush(k, now_ns) {
                    collect_ready(&mut st, bucket, &mut ready);
                }
            }
        }
        for w in ready {
            // Submission accounting is pod-granular, matching the SPMD
            // flusher's one-enqueue-per-bucket semantics.
            if let Err(w) = self.shared.front.enqueue(w, now_ns) {
                fail_work(
                    w,
                    ServeError::Failed("mpmd service is shut down".to_string()),
                    self.shared.node.tracer(),
                    now_ns,
                );
            } else {
                self.shared.node.metrics().add_service_submission();
            }
        }
        Ok(handle)
    }

    fn batched_decision<S: Scalar>(&self, routine: SmallRoutine, class: usize) -> bool {
        let key = (routine, S::DTYPE, class as u32);
        let mut st = self.small.lock().unwrap();
        if let Some(&win) = st.decisions.get(&key) {
            return win;
        }
        let predictor = Predictor {
            model: self.shared.cfg.model.clone(),
            topo: self.shared.node.topology().clone(),
            dtype: S::DTYPE,
        };
        let win = predictor.batched_wins(
            routine.name(),
            class,
            1,
            self.shared.cfg.tile,
            self.shared.workers.len(),
            self.shared.cfg.policy.max_batch,
        );
        st.decisions.insert(key, win);
        win
    }

    /// Flush buckets whose oldest request dwelled past the bound.
    pub fn flush_due_small(&self) {
        flush_due_buckets(&self.shared, &self.small);
    }

    /// Force-flush every pending coalescer bucket.
    pub fn flush_small(&self) {
        let now_ns = self.shared.sim_now_ns();
        let mut ready = Vec::new();
        {
            let mut st = self.small.lock().unwrap();
            for bucket in st.planner.flush_all(now_ns) {
                collect_ready(&mut st, bucket, &mut ready);
            }
        }
        for w in ready {
            if let Err(w) = self.shared.front.enqueue(w, now_ns) {
                fail_work(
                    w,
                    ServeError::Failed("mpmd service is shut down".to_string()),
                    self.shared.node.tracer(),
                    now_ns,
                );
            } else {
                self.shared.node.metrics().add_service_submission();
            }
        }
    }

    /// Small solves waiting in the coalescer (not yet flushed).
    pub fn pending_small(&self) -> usize {
        self.small.lock().unwrap().planner.pending()
    }

    /// Simulate worker `d`'s process dying right now: its staged
    /// shards vanish (exports revoked), pending mailbox work re-routes,
    /// and in-flight solves that touched its shards re-queue with the
    /// device excluded.
    pub fn kill_worker(&self, d: usize) -> Result<()> {
        let link = self
            .shared
            .workers
            .get(d)
            .ok_or(Error::InvalidDevice { device: d, count: self.shared.workers.len() })?;
        link.kill();
        let tr = self.shared.node.tracer();
        if tr.enabled() {
            tr.decision(
                TraceId(0),
                self.shared.sim_now_ns(),
                "kill",
                format!("worker {d} killed; staged shards revoked, resident factors invalidated"),
            );
        }
        // The dead process's staged shards are gone — every factor
        // with a shard on `d` loses its residency (pinned entries are
        // doomed; the in-flight hit's own death handling re-queues).
        self.shared.invalidate_factors_on(d);
        Ok(())
    }

    /// Arm the chaos fault injector: the next job worker `d` processes
    /// panics, exercising the panic-death path end to end.
    pub fn inject_worker_fault(&self, d: usize) -> Result<()> {
        let link = self
            .shared
            .workers
            .get(d)
            .ok_or(Error::InvalidDevice { device: d, count: self.shared.workers.len() })?;
        link.ctx.arm_fault();
        Ok(())
    }

    /// Inject a straggler: device `d`'s clock runs `factor`× slower
    /// from now on (every charge it hosts stretches), generalizing the
    /// kill drill to *slow* rather than dead hardware. The worker stays
    /// alive and keeps serving — no request is lost — while
    /// deadline-miss accounting relaxes by
    /// [`SchedConfig::degrade_factor`] for as long as any straggler is
    /// active. `factor` is clamped to ≥ 1.0.
    pub fn inject_straggler(&self, d: usize, factor: f64) -> Result<()> {
        self.shared.node.device(d)?.clock().set_drag(factor.max(1.0));
        let tr = self.shared.node.tracer();
        if tr.enabled() {
            tr.decision(
                TraceId(0),
                self.shared.sim_now_ns(),
                "straggler",
                format!("device {d} dragged {:.2}x; deadline accounting degraded", factor.max(1.0)),
            );
        }
        // A dragged device degrades every hit its shards would serve —
        // cached factors touching it lose residency and repeat solves
        // refactor cold over the degraded view.
        self.shared.invalidate_factors_on(d);
        Ok(())
    }

    /// Restore device `d`'s clock to nominal speed.
    pub fn clear_straggler(&self, d: usize) -> Result<()> {
        self.shared.node.device(d)?.clock().set_drag(1.0);
        Ok(())
    }

    /// True while any device clock runs with straggler drag.
    pub fn degraded(&self) -> bool {
        (0..self.shared.node.num_devices()).any(|d| {
            self.shared.node.device(d).map(|g| g.clock().drag() > 1.0).unwrap_or(false)
        })
    }

    /// The active scheduler configuration.
    pub fn sched_config(&self) -> SchedConfig {
        self.shared.cfg.sched
    }

    /// Bytes currently admitted for `tenant` (0 without quotas).
    pub fn tenant_admitted(&self, tenant: u32) -> usize {
        self.shared.quotas.admitted(tenant)
    }

    /// High-water mark of admitted bytes for `tenant` — the
    /// over-admission proof the quota property test pins.
    pub fn tenant_peak(&self, tenant: u32) -> usize {
        self.shared.quotas.peak(tenant)
    }

    /// Devices whose worker process is alive.
    pub fn alive_workers(&self) -> Vec<usize> {
        self.shared.live_workers(&[])
    }

    /// Resident factors currently cached (live entries).
    pub fn cached_factors(&self) -> usize {
        self.shared.cache.lock().unwrap().len()
    }

    /// Device bytes held by resident factors across workers.
    pub fn cached_factor_bytes(&self) -> usize {
        self.shared.cache.lock().unwrap().resident_bytes()
    }

    /// Evict every resident factor; returns how many were dropped.
    pub fn evict_cached_factors(&self) -> usize {
        let mut n = 0;
        while self.shared.evict_factor() {
            n += 1;
        }
        n
    }

    /// Per-worker mailbox depths (the queue-depth gauge behind the
    /// `mpmd_peak_worker_queue` metric).
    pub fn worker_queue_depths(&self) -> Vec<usize> {
        self.shared.workers.iter().map(|w| w.queue_depth()).collect()
    }

    /// Per-worker reserved bytes (each worker's own accountant).
    pub fn reserved(&self) -> Vec<usize> {
        self.shared.workers.iter().map(|w| w.ctx.admission.reserved()).collect()
    }

    /// Per-worker reservation high-water marks.
    pub fn peak_reserved(&self) -> Vec<usize> {
        self.shared.workers.iter().map(|w| w.ctx.admission.peak_reserved()).collect()
    }

    /// Requests queued at the frontend (not yet dispatched).
    pub fn pending(&self) -> usize {
        self.shared.front.state.lock().unwrap().queue.len()
    }

    /// Requests dispatched and not yet resolved.
    pub fn in_flight(&self) -> usize {
        self.shared.front.state.lock().unwrap().in_flight
    }

    /// The node this service serves.
    pub fn node(&self) -> &SimNode {
        &self.shared.node
    }

    /// The node-wide tracer (request spans, decision log, drift
    /// monitor — see `crate::obs` and `OBSERVABILITY.md`). Enable it
    /// *before* submitting to capture complete span trees.
    pub fn tracer(&self) -> &Arc<Tracer> {
        self.shared.node.tracer()
    }

    /// The active configuration.
    pub fn config(&self) -> &MpmdConfig {
        &self.shared.cfg
    }

    /// The IPC registry (per-process open/export accounting lives
    /// here; see `crate::ipc`).
    pub fn registry(&self) -> &Arc<IpcRegistry> {
        &self.shared.registry
    }

    /// Block until every submitted request has resolved (published to
    /// its handle) — partial coalescer buckets are force-flushed first.
    pub fn drain(&self) {
        self.flush_small();
        let mut st = self.shared.front.state.lock().unwrap();
        while !st.queue.is_empty() || st.in_flight > 0 {
            let (guard, _) =
                self.shared.front.cv.wait_timeout(st, Duration::from_millis(20)).unwrap();
            st = guard;
        }
    }
}

impl Drop for MpmdService {
    fn drop(&mut self) {
        // Flush stragglers so their waiters resolve, then let the
        // dispatcher drain the queue to zero before stopping anything.
        self.flush_small();
        {
            let mut st = self.shared.front.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.front.cv.notify_all();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // Resident factors die with the service: tear them down while
        // the workers can still revoke + free their staged shards.
        let drained = self.shared.cache.lock().unwrap().drain();
        for (_, e) in drained {
            self.shared.teardown_factor(&e);
        }
        // Routers next (their jobs need live workers), workers last.
        self.routers = None;
        for w in &self.shared.workers {
            w.close();
        }
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
    }
}
