//! MPMD serving subsystem: one (simulated) process per GPU, shards
//! published over IPC, a rank-0 frontend with failure-aware routing —
//! the paper's Figure 2 (right) as a *production* serving shape.
//!
//! `coordinator::mpmd::gather_pointers_mpmd` demonstrates the
//! single-caller pointer gather once; this module runs the whole
//! deployment persistently:
//!
//! * **Workers** ([`worker`]) — one per device, each a simulated
//!   process: its own [`crate::ipc::AddressSpace`], its own mailbox
//!   thread, its own [`crate::coordinator::DeviceAdmission`] accountant
//!   over exactly its device's VRAM. A worker stages its shard of every
//!   distributed solve locally (building the very panel bytes a
//!   single-caller scatter would — bitwise), exports it through the
//!   **bound** [`crate::ipc::IpcRegistry`] lifecycle (freeing a shard
//!   revokes its handles), and sweeps coalesced pods pinned to its
//!   device.
//! * **Frontend** ([`frontend`]) — rank 0 owns the FIFO request queue
//!   and routes: distributed solves open the workers' handles and run
//!   `potrf/potrs/potri/syevd_dist` as the single caller (paying the
//!   modeled `cudaIpc` round-trip that
//!   [`Predictor::mpmd_overhead`](crate::costmodel::Predictor::mpmd_overhead)
//!   projects); small solves coalesce and pin one pod per worker.
//!   Worker death — panic or [`MpmdService::kill_worker`] — loses no
//!   requests: in-flight work re-queues with the dead device excluded
//!   and completes on the remaining ones, over a degraded
//!   [`crate::device::SimNode::subset`] view.
//!
//! Numerics are bitwise-identical to the SPMD
//! [`crate::coordinator::SolveService`] path (same layouts, same
//! solver schedule — pinned in `rust/tests/mpmd_serve.rs` for all four
//! dtypes); see the SPMD-vs-MPMD decision table in
//! [`crate::coordinator`]. `examples/mpmd_serve.rs` drives the full
//! story, `benches/serving.rs` measures the two fronts side by side,
//! and EXPERIMENTS.md records the overhead table.

mod frontend;
mod worker;

pub use frontend::{DistRoutine, MpmdConfig, MpmdService};
