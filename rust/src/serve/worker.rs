//! The simulated one-process-per-GPU worker.
//!
//! Each worker models one MPMD rank: its own [`AddressSpace`] (raw
//! pointers from other ranks are meaningless to it), its own thread
//! draining a FIFO **mailbox** of jobs, its own [`DeviceAdmission`]
//! accountant over exactly one device's VRAM, and a ledger of the
//! shard allocations it has staged and exported. The rank-0 frontend
//! (`super::frontend`) talks to workers two ways, mirroring the real
//! split:
//!
//! * **data-plane work** — shard staging and pinned pod sweeps — goes
//!   through the mailbox and executes on the worker's thread, as it
//!   would in the worker's process;
//! * **control-plane bookkeeping** — reserve/release on the admission
//!   accountant, teardown of staged shards — is invoked directly on
//!   the shared [`WorkerCtx`] (the RPC the real frontend would issue),
//!   which keeps the lock graph trivially acyclic.
//!
//! ## Death
//!
//! A worker dies two ways: a **panic** inside a mailbox job (including
//! the injected fault used by the chaos tests), or an explicit
//! [`WorkerLink::kill`] from the frontend. Both paths converge on the
//! same simulation of process death: the alive flag drops, every
//! staged allocation is freed (its exported handles revoked first —
//! the revoke-on-free discipline), and the mailbox is drained with
//! each pending job run in **dead mode**. The job contract makes dead
//! mode safe: every job checks [`WorkerCtx::alive`] first and behaves
//! as the dead process would — staging jobs simply drop their reply
//! channel (the frontend sees the disconnect), pod jobs hand their
//! request back to the frontend for re-queueing on another device.
//! In-flight distributed solves that were reading this worker's shards
//! start failing on the freed allocations; the router classifies the
//! error against the live set and re-queues with this device excluded.

use super::frontend::FrontShared;
use crate::coordinator::DeviceAdmission;
use crate::costmodel::GpuCostModel;
use crate::device::{DevPtr, SimNode};
use crate::ipc::{AddressSpace, IpcRegistry};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A job executed on the worker's thread (its simulated process).
/// Contract: the job MUST check [`WorkerCtx::alive`] first and take its
/// dead-mode path when the worker has died (see the module docs).
pub(crate) type WorkerJob = Box<dyn FnOnce(&WorkerCtx) + Send + 'static>;

/// One shard allocation this worker has staged (and possibly exported;
/// reclaim revokes **every** handle over the pointer, so none is
/// recorded here).
pub(crate) struct StagedAlloc {
    /// The node view the allocation was made through (a subset view in
    /// degraded mode) — pointers are node-relative, so frees must go
    /// through the same view.
    pub(crate) node: SimNode,
    pub(crate) ptr: DevPtr,
}

/// The worker's shared state: everything both its own thread and the
/// frontend (admission RPCs, teardown, kill) may touch.
pub(crate) struct WorkerCtx {
    /// Physical device ordinal this worker owns (its rank).
    pub(crate) device: usize,
    /// The worker's virtual address space.
    pub(crate) space: AddressSpace,
    /// The full node (pods run here, pinned to `device`).
    pub(crate) node: SimNode,
    pub(crate) registry: Arc<IpcRegistry>,
    /// This worker's own Footprint admission over its device's VRAM.
    pub(crate) admission: DeviceAdmission,
    /// Cost model for worker-executed sweeps.
    pub(crate) model: GpuCostModel,
    alive: AtomicBool,
    /// Fault injection: the next mailbox job panics (chaos testing).
    fault: AtomicBool,
    /// Shards staged by this worker, freed wholesale on death.
    staged: Mutex<Vec<StagedAlloc>>,
    /// Wake-ups back to the rank-0 frontend (releases, death, requeues).
    pub(crate) front: Arc<FrontShared>,
}

impl WorkerCtx {
    pub(crate) fn new(
        device: usize,
        node: SimNode,
        registry: Arc<IpcRegistry>,
        model: GpuCostModel,
        front: Arc<FrontShared>,
    ) -> Self {
        let capacity = node
            .memory_reports()
            .get(device)
            .map(|r| r.capacity)
            .expect("worker device exists");
        WorkerCtx {
            device,
            space: AddressSpace(device),
            admission: DeviceAdmission::new(device, capacity),
            node,
            registry,
            model,
            alive: AtomicBool::new(true),
            fault: AtomicBool::new(false),
            staged: Mutex::new(Vec::new()),
            front,
        }
    }

    /// Whether the worker process is still alive.
    pub(crate) fn alive(&self) -> bool {
        self.alive.load(Ordering::SeqCst)
    }

    /// Arm the fault injector: the next mailbox job panics.
    pub(crate) fn arm_fault(&self) {
        self.fault.store(true, Ordering::SeqCst);
    }

    fn take_fault(&self) -> bool {
        self.fault.swap(false, Ordering::SeqCst)
    }

    /// Record a staged (and possibly exported) shard allocation. If
    /// the process died while the staging job was mid-flight (a kill
    /// racing the job's entry alive-check), the ledger may already have
    /// been drained — reclaim immediately so a dead worker never holds
    /// a live shard, whatever the interleaving.
    pub(crate) fn record_staged(&self, alloc: StagedAlloc) {
        self.staged.lock().unwrap().push(alloc);
        if !self.alive() {
            self.free_all_staged();
        }
    }

    /// Tear down one staged shard: revoke its export (revoke-on-free),
    /// free the allocation, and wake the frontend. Idempotent — a shard
    /// already reclaimed by death is skipped.
    pub(crate) fn release_staged(&self, ptr: DevPtr) {
        let entry = {
            let mut staged = self.staged.lock().unwrap();
            let idx = staged.iter().position(|s| s.ptr == ptr);
            idx.map(|i| staged.swap_remove(i))
        };
        if let Some(s) = entry {
            self.reclaim(s);
        }
        self.front.notify();
    }

    /// Free every staged shard — the process-death path (also the
    /// clean-shutdown sweep; by then the list is normally empty).
    pub(crate) fn free_all_staged(&self) {
        let drained: Vec<StagedAlloc> = std::mem::take(&mut *self.staged.lock().unwrap());
        for s in drained {
            self.reclaim(s);
        }
    }

    fn reclaim(&self, s: StagedAlloc) {
        // Revoke-on-free: every handle this worker exported over the
        // pointer dies before the memory does (the bound-export
        // liveness check would only catch *subsequent* opens lazily,
        // and without the accounting below).
        let revoked = self.registry.revoke_all_for(self.space, s.ptr);
        if revoked > 0 {
            self.node.metrics().add_ipc_revokes(revoked as u64);
        }
        let _ = s.node.free(s.ptr);
    }

    fn mark_dead(&self) {
        self.alive.store(false, Ordering::SeqCst);
    }
}

struct MailboxState {
    jobs: VecDeque<WorkerJob>,
    closed: bool,
}

/// The worker's FIFO mailbox (the message channel into its process).
pub(crate) struct Mailbox {
    state: Mutex<MailboxState>,
    cv: Condvar,
}

impl Mailbox {
    fn new() -> Self {
        Mailbox {
            state: Mutex::new(MailboxState { jobs: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a job; returns the resulting depth, or `Err(job)` when
    /// the mailbox is closed (worker dead/shut down).
    fn push(&self, job: WorkerJob) -> Result<usize, WorkerJob> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(job);
        }
        st.jobs.push_back(job);
        let depth = st.jobs.len();
        drop(st);
        self.cv.notify_one();
        Ok(depth)
    }

    /// Blocking pop; `None` once the mailbox is closed and empty.
    fn pop(&self) -> Option<WorkerJob> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(job) = st.jobs.pop_front() {
                return Some(job);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Close the mailbox and take every pending job.
    fn close_and_drain(&self) -> Vec<WorkerJob> {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        let jobs = st.jobs.drain(..).collect();
        drop(st);
        self.cv.notify_all();
        jobs
    }

    fn depth(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }
}

/// The frontend's handle to one worker.
pub(crate) struct WorkerLink {
    pub(crate) ctx: Arc<WorkerCtx>,
    mailbox: Arc<Mailbox>,
}

impl WorkerLink {
    /// Send a job to the worker's mailbox. `Err(job)` when the worker
    /// is dead or shut down (the caller re-routes).
    pub(crate) fn send(&self, job: WorkerJob) -> Result<(), WorkerJob> {
        if !self.ctx.alive() {
            return Err(job);
        }
        let depth = self.mailbox.push(job)?;
        self.ctx.node.metrics().note_worker_queue_depth(depth as u64);
        Ok(())
    }

    /// Jobs waiting in the mailbox (the per-worker queue depth gauge).
    pub(crate) fn queue_depth(&self) -> usize {
        self.mailbox.depth()
    }

    /// Whether the worker process is alive.
    pub(crate) fn alive(&self) -> bool {
        self.ctx.alive()
    }

    /// Simulate the worker process dying *now*: the alive flag drops,
    /// its staged shards vanish (handles revoked, memory freed — any
    /// in-flight solve reading them starts failing), and every pending
    /// mailbox job runs in dead mode on the calling thread (staging
    /// jobs drop their reply channels, pod jobs re-queue themselves).
    pub(crate) fn kill(&self) {
        self.ctx.mark_dead();
        let drained = self.mailbox.close_and_drain();
        self.ctx.free_all_staged();
        for job in drained {
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(&self.ctx)));
        }
        self.ctx.front.notify();
    }

    /// Clean shutdown: close the mailbox so the worker thread exits
    /// once it has drained (used by the service's `Drop`, after the
    /// request queue is empty).
    pub(crate) fn close(&self) {
        let drained = self.mailbox.close_and_drain();
        for job in drained {
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(&self.ctx)));
        }
    }
}

/// Spawn a worker: its context, link, and process thread.
pub(crate) fn spawn_worker(ctx: WorkerCtx) -> (WorkerLink, std::thread::JoinHandle<()>) {
    let ctx = Arc::new(ctx);
    let mailbox = Arc::new(Mailbox::new());
    let thread = {
        let ctx = ctx.clone();
        let mailbox = mailbox.clone();
        std::thread::spawn(move || worker_loop(&ctx, &mailbox))
    };
    (WorkerLink { ctx, mailbox }, thread)
}

fn worker_loop(ctx: &Arc<WorkerCtx>, mailbox: &Arc<Mailbox>) {
    while let Some(job) = mailbox.pop() {
        if ctx.take_fault() {
            // Injected crash (chaos testing): the process dies *before*
            // touching this job. Die first, then run the job — and the
            // backlog — in dead mode so nothing is silently dropped
            // (staging jobs drop their reply channels, pods re-queue).
            die(ctx, mailbox, Some(job));
            return;
        }
        let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(ctx)));
        if ran.is_err() {
            // The process died mid-job (the job itself unwound, so its
            // waiters are handled by the disconnect/requeue contract);
            // tear down and drain the backlog in dead mode.
            die(ctx, mailbox, None);
            return;
        }
    }
}

/// The one death sequence (panic, injected fault): mark dead, free the
/// staged shards (revoking their exports — any in-flight solve reading
/// them starts failing), run the pending jobs in dead mode, wake rank 0.
fn die(ctx: &Arc<WorkerCtx>, mailbox: &Arc<Mailbox>, current: Option<WorkerJob>) {
    ctx.mark_dead();
    ctx.free_all_staged();
    if let Some(job) = current {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(ctx)));
    }
    for j in mailbox.close_and_drain() {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| j(ctx)));
    }
    ctx.front.notify();
}
