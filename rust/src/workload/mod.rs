//! Fleet-scale traffic generation for the serving fronts.
//!
//! The scheduler work in `coordinator::admit` (SLO classes, EDF/SJF,
//! quotas, preemption) is only as good as the traffic that exercises
//! it. This module carries deterministic **open-loop** and
//! **closed-loop** generators over mixed request populations drawn from
//! the domain examples — the GP posterior pipeline (`gp_inverse.rs`)
//! and the VMC stochastic-reconfiguration loop (`vmc_sr.rs`) — plus the
//! tiny-solve stream and nightly refactorizations of the batch demos.
//!
//! Three arrival processes ([`ArrivalProcess`]):
//!
//! * **Poisson** — memoryless arrivals at a fixed rate; the steady-state
//!   baseline.
//! * **Diurnal** — a sinusoidally rate-modulated Poisson process
//!   (base → peak over a period); stresses admission under slow load
//!   swings.
//! * **Bursty** — a two-point mixture of burst-rate and idle-rate
//!   exponential gaps; produces the head-of-line pileups that separate
//!   FIFO from EDF/SJF on tail latency.
//!
//! Everything is driven by the crate's [`Rng`] (xoshiro256**): one
//! 64-bit seed reproduces the whole trace — arrival instants, request
//! mix, and every input matrix (each request carries its own derived
//! matrix seed).
//!
//! The open-loop driver paces the **simulated** clock with
//! [`SimNode::sync_clocks_to_ns`]: a request arriving at `t` advances
//! an idle fleet to `t`, so cost-model queue waits are measured from
//! the arrival instant — wall time never enters the accounting.

use crate::batch::SmallRoutine;
use crate::coordinator::{
    DistRoutine, ServeError, ServiceHandle, Slo, SloClass, SolveService, SolveStats,
};
use crate::device::SimNode;
use crate::error::Result;
use crate::linalg::Matrix;
use crate::obs::TraceId;
use crate::rng::Rng;
use crate::scalar::{c32, c64, DType, Scalar};
use crate::solver::MixedCapable;
use std::collections::VecDeque;

// ---------------------------------------------------------------------------
// Arrival processes
// ---------------------------------------------------------------------------

/// How request arrival instants are spaced. All three draw exponential
/// gaps; they differ in how the instantaneous rate is chosen.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at a constant `rate_hz`.
    Poisson {
        /// Mean arrival rate (requests per simulated second).
        rate_hz: f64,
    },
    /// Sinusoidally rate-modulated Poisson:
    /// `rate(t) = base + (peak − base) · (1 + sin(2πt/period)) / 2`.
    Diurnal {
        /// Trough arrival rate.
        base_hz: f64,
        /// Crest arrival rate.
        peak_hz: f64,
        /// Modulation period in simulated seconds.
        period_s: f64,
    },
    /// Two-point mixture: each gap is drawn at `burst_hz` with
    /// probability `burst_prob`, else at `idle_hz`. With a large rate
    /// ratio this yields tight arrival clusters separated by lulls —
    /// the pileups that expose FIFO head-of-line blocking.
    Bursty {
        /// Background arrival rate between bursts.
        idle_hz: f64,
        /// In-burst arrival rate.
        burst_hz: f64,
        /// Probability a given gap is drawn at the burst rate.
        burst_prob: f64,
    },
}

/// One exponential gap at `rate` (inverse-CDF; `1 − u` keeps the
/// argument of `ln` strictly positive since `u ∈ [0, 1)`).
fn exp_gap_s(rate_hz: f64, rng: &mut Rng) -> f64 {
    let u = rng.next_f64();
    -(1.0 - u).ln() / rate_hz.max(1e-12)
}

impl ArrivalProcess {
    /// Draw the gap (simulated seconds) to the next arrival, given the
    /// current simulated time `t_s` (only [`ArrivalProcess::Diurnal`]
    /// reads it).
    pub fn next_gap_s(&self, t_s: f64, rng: &mut Rng) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_hz } => exp_gap_s(rate_hz, rng),
            ArrivalProcess::Diurnal { base_hz, peak_hz, period_s } => {
                let phase = t_s / period_s.max(1e-12) * std::f64::consts::TAU;
                let rate = base_hz + (peak_hz - base_hz) * 0.5 * (1.0 + phase.sin());
                exp_gap_s(rate, rng)
            }
            ArrivalProcess::Bursty { idle_hz, burst_hz, burst_prob } => {
                if rng.next_f64() < burst_prob {
                    exp_gap_s(burst_hz, rng)
                } else {
                    exp_gap_s(idle_hz, rng)
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Request population
// ---------------------------------------------------------------------------

/// Which serving path a generated request takes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// The batched small-solve path (`submit_small_slo`).
    Small(SmallRoutine),
    /// The planned distributed path (`submit_dist_slo`, or
    /// `submit_syevd_slo` for [`DistRoutine::Syevd`]).
    Dist(DistRoutine),
}

/// One generated request: route, problem shape, dtype, and SLO terms.
/// `Copy` so populations are plain value tables.
#[derive(Clone, Copy, Debug)]
pub struct RequestSpec {
    /// Serving path and routine.
    pub route: Route,
    /// Matrix order.
    pub n: usize,
    /// Right-hand-side columns (read only by `potrs` routes).
    pub nrhs: usize,
    /// Element type; the driver monomorphizes the submit on this.
    pub dtype: DType,
    /// Scheduling class.
    pub class: SloClass,
    /// Deadline **budget** in cost-model ns from the arrival instant
    /// (`None` = no deadline). The driver turns it into the absolute
    /// [`Slo::deadline_ns`] at submit time.
    pub deadline_budget_ns: Option<u64>,
    /// Owning tenant for quota accounting.
    pub tenant: u32,
    /// Residual tolerance carried to the service
    /// ([`Slo::with_tolerance`]): `Some` lets the planner route the
    /// solve through [`crate::solver::Precision::Mixed`] when the cost
    /// model predicts a win.
    pub tol: Option<f64>,
    /// Condition-number target of the generated SPD input (`1.0`
    /// keeps the default well-conditioned `spd_random` draw); when
    /// `tol` is set this is also the κ budget the router prices the
    /// refinement iteration count with.
    pub cond: f64,
    /// Seed for this request's input matrices; [`Population::sample`]
    /// re-derives it per draw so every request gets fresh inputs.
    pub seed: u64,
}

impl RequestSpec {
    /// The absolute [`Slo`] for a request arriving at `now_ns`.
    pub fn slo_at(&self, now_ns: u64) -> Slo {
        let slo = Slo {
            class: self.class,
            deadline_ns: self.deadline_budget_ns.map(|b| now_ns.saturating_add(b)),
            tenant: self.tenant,
            numeric: None,
        };
        match self.tol {
            Some(tol) => slo.with_tolerance(tol, self.cond.max(1.0)),
            None => slo,
        }
    }
}

/// Reuse correlation of sampled matrix seeds
/// ([`Population::with_reuse`]): real fleets re-solve against the same
/// operator — a GP posterior queried many times between kernel
/// refits, a VMC geometric tensor shared across an optimizer sweep —
/// so repeat draws land on a small hot set of matrices instead of a
/// fresh one per request. This is the traffic shape the factor cache
/// ([`crate::coordinator::SmallConfig::factor_cache`]) is built for.
#[derive(Clone, Copy, Debug)]
pub struct ReusePolicy {
    /// Size of the hot working set: a repeat draw lands on one of
    /// `hot` fixed seeds (shared across templates; the matrix itself
    /// still differs per template's `n`/dtype).
    pub hot: usize,
    /// Probability a draw churns to a fresh never-repeated seed
    /// instead of a hot one (`0.0` = pure reuse, `1.0` = the default
    /// fresh-per-request behavior).
    pub churn: f64,
}

/// A weighted mixture of [`RequestSpec`] templates.
#[derive(Clone, Debug)]
pub struct Population {
    entries: Vec<(f64, RequestSpec)>,
    total: f64,
    reuse: Option<ReusePolicy>,
}

/// Base of the hot-seed pool: hot seed `k` is this xor a golden-ratio
/// multiple of `k`, so the pool is fixed across runs and disjoint
/// draws of `k` decorrelate.
const HOT_SEED_BASE: u64 = 0x9D5C_41C3_1E5F_7A26;

impl Population {
    /// Build from `(weight, template)` pairs. Weights are relative
    /// (they need not sum to 1); non-positive weights are rejected.
    pub fn new(entries: Vec<(f64, RequestSpec)>) -> Self {
        assert!(!entries.is_empty(), "population must have at least one entry");
        assert!(entries.iter().all(|&(w, _)| w > 0.0), "weights must be positive");
        let total = entries.iter().map(|&(w, _)| w).sum();
        Population { entries, total, reuse: None }
    }

    /// Correlate matrix seeds across draws: with probability
    /// `1 − churn` a request re-solves one of `hot` fixed matrices.
    /// Sampling stays deterministic under the trace seed — the reuse
    /// decisions ride the same xoshiro stream as everything else.
    pub fn with_reuse(mut self, hot: usize, churn: f64) -> Self {
        self.reuse = Some(ReusePolicy { hot: hot.max(1), churn: churn.clamp(0.0, 1.0) });
        self
    }

    /// The active reuse policy, if any.
    pub fn reuse(&self) -> Option<ReusePolicy> {
        self.reuse
    }

    /// Draw one request: weighted template pick, then the matrix seed —
    /// fresh from the stream by default; under [`Self::with_reuse`], a
    /// hot-set seed with probability `1 − churn`.
    pub fn sample(&self, rng: &mut Rng) -> RequestSpec {
        let mut x = rng.next_f64() * self.total;
        let mut spec = self.entries.last().expect("population is non-empty").1;
        for &(w, s) in &self.entries {
            if x < w {
                spec = s;
                break;
            }
            x -= w;
        }
        spec.seed = match self.reuse {
            None => rng.next_u64(),
            Some(r) => {
                if rng.next_f64() < r.churn {
                    rng.next_u64()
                } else {
                    let k = rng.next_u64() % r.hot as u64;
                    HOT_SEED_BASE ^ k.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                }
            }
        };
        spec
    }

    /// The template table (for reporting / assertions).
    pub fn entries(&self) -> &[(f64, RequestSpec)] {
        &self.entries
    }

    /// The fleet mix drawn from the domain examples:
    ///
    /// * **VMC SR solves** (`vmc_sr.rs`): `potrs` on the `n = 96`
    ///   quantum geometric tensor, one RHS — the inner loop of an
    ///   optimizer, so interactive with a tight deadline.
    /// * **GP posterior solves** (`gp_inverse.rs`): `potrs` against the
    ///   `n = 256` RBF kernel — interactive, looser deadline.
    /// * **GP kernel inversions**: the same kernel through `potri`
    ///   (real analogue of Fig. 3b's complex128 inversion), plus a
    ///   complex128 `potri` at `n = 192` — standard class.
    /// * **Tiny solves** (`batch_serve.rs`): `potrs` at `n ∈ {12, 21,
    ///   30}` — the coalescer's bread and butter, standard class.
    /// * **Nightly refactorizations**: `potrf` at `n = 384`, float32 —
    ///   batch class, no deadline; the preemptible background work.
    pub fn gp_vmc_mix() -> Self {
        let dist = |r, n, nrhs, dtype, class, budget: Option<u64>, tenant| RequestSpec {
            route: Route::Dist(r),
            n,
            nrhs,
            dtype,
            class,
            deadline_budget_ns: budget,
            tenant,
            tol: None,
            cond: 1.0,
            seed: 0,
        };
        let small = |n, tenant| RequestSpec {
            route: Route::Small(SmallRoutine::Potrs),
            n,
            nrhs: 1,
            dtype: DType::F64,
            class: SloClass::Standard,
            deadline_budget_ns: None,
            tenant,
            tol: None,
            cond: 1.0,
            seed: 0,
        };
        Population::new(vec![
            // VMC stochastic reconfiguration: (S + λI)δ = g, n_params = 96.
            (
                0.30,
                dist(
                    DistRoutine::Potrs,
                    96,
                    1,
                    DType::F64,
                    SloClass::Interactive,
                    Some(25_000_000),
                    1,
                ),
            ),
            // GP posterior mean: K⁻¹y against the 256-point RBF kernel.
            (
                0.20,
                dist(
                    DistRoutine::Potrs,
                    256,
                    1,
                    DType::F64,
                    SloClass::Interactive,
                    Some(80_000_000),
                    2,
                ),
            ),
            // GP kernel inversion (posterior variance needs all of K⁻¹).
            (0.12, dist(DistRoutine::Potri, 256, 0, DType::F64, SloClass::Standard, None, 2)),
            // Fig. 3b's dtype: complex128 Cholesky inverse.
            (0.05, dist(DistRoutine::Potri, 192, 0, DType::C128, SloClass::Standard, None, 2)),
            // The tiny-solve stream (three size-classes, as batch_serve.rs).
            (0.08, small(12, 1)),
            (0.08, small(21, 1)),
            (0.07, small(30, 1)),
            // Nightly refactorization: big, float32, happy to wait.
            (0.10, dist(DistRoutine::Potrf, 384, 0, DType::F32, SloClass::Batch, None, 3)),
        ])
    }

    /// [`Self::gp_vmc_mix`] with reuse-correlated inputs: `hot` hot
    /// matrices, `churn` probability of a fresh one — the repeat-solve
    /// regime where the factor cache converts potrf time into hits.
    pub fn gp_vmc_mix_reuse(hot: usize, churn: f64) -> Self {
        Self::gp_vmc_mix().with_reuse(hot, churn)
    }

    /// The two-tier-fabric fleet mix ([`crate::fabric::Fabric`]):
    /// [`Self::gp_vmc_mix`]'s request classes rebalanced toward the
    /// mid-size distributed solves a multi-island deployment actually
    /// serves, across four tenants. Every template sits **below** the
    /// planner's 1-node-vs-2-node crossover, so on a 2×8 fabric the
    /// router confines each solve to one island's device prefix —
    /// both islands stay independently busy, and what this mix
    /// exercises is per-island admission and narrow-plan scheduling,
    /// not the inter-node links. Spanning solves are the offline
    /// crossover-ladder regime (`benches/fabric.rs`, EXPERIMENTS.md),
    /// not fleet traffic.
    pub fn fabric_mix() -> Self {
        let dist = |r, n, nrhs, dtype, class, budget: Option<u64>, tenant| RequestSpec {
            route: Route::Dist(r),
            n,
            nrhs,
            dtype,
            class,
            deadline_budget_ns: budget,
            tenant,
            tol: None,
            cond: 1.0,
            seed: 0,
        };
        Population::new(vec![
            // The VMC inner loop, unchanged from `gp_vmc_mix`.
            (
                0.25,
                dist(
                    DistRoutine::Potrs,
                    96,
                    1,
                    DType::F64,
                    SloClass::Interactive,
                    Some(25_000_000),
                    1,
                ),
            ),
            // GP posterior mean against a larger kernel.
            (
                0.20,
                dist(
                    DistRoutine::Potrs,
                    256,
                    1,
                    DType::F64,
                    SloClass::Interactive,
                    Some(80_000_000),
                    2,
                ),
            ),
            // Posterior sweeps: one factor amortized over a block of RHS.
            (0.15, dist(DistRoutine::Potrs, 192, 4, DType::F64, SloClass::Standard, None, 2)),
            // Kernel inversion for a compact posterior covariance. Kept
            // deliberately small: potri's trmm/lauum tail is flop-dense
            // enough that even mid sizes profit from spanning both
            // islands, so only the small end stays island-confined.
            (0.10, dist(DistRoutine::Potri, 64, 0, DType::F64, SloClass::Standard, None, 3)),
            // Spectral preconditioner refresh.
            (0.10, dist(DistRoutine::Syevd, 256, 0, DType::F64, SloClass::Standard, None, 3)),
            // The coalescer's tiny-solve stream.
            (
                0.05,
                RequestSpec {
                    route: Route::Small(SmallRoutine::Potrs),
                    n: 12,
                    nrhs: 1,
                    dtype: DType::F64,
                    class: SloClass::Standard,
                    deadline_budget_ns: None,
                    tenant: 1,
                    tol: None,
                    cond: 1.0,
                    seed: 0,
                },
            ),
            (
                0.05,
                RequestSpec {
                    route: Route::Small(SmallRoutine::Potrs),
                    n: 30,
                    nrhs: 1,
                    dtype: DType::F64,
                    class: SloClass::Standard,
                    deadline_budget_ns: None,
                    tenant: 1,
                    tol: None,
                    cond: 1.0,
                    seed: 0,
                },
            ),
            // Nightly refactorization: big, float32, happy to wait.
            (0.10, dist(DistRoutine::Potrf, 768, 0, DType::F32, SloClass::Batch, None, 4)),
        ])
    }

    /// The mixed-precision regime sweep: `potrs` templates carrying a
    /// residual tolerance and a condition-number budget, spanning the
    /// three behaviors of the tier —
    ///
    /// * **convergence** — well-conditioned f64 and c128 systems whose
    ///   refinement meets the requested tolerance in a few iterations;
    /// * **the iteration cap** — a tolerance below the f64 residual
    ///   floor `κ·ε_f64`: the router declines it up front (a guaranteed
    ///   stall is never priced as the cheap tier), and when the mixed
    ///   tier is *forced* at the solver layer the refinement plateaus,
    ///   trips the stall check → typed full-precision fallback;
    /// * **the routing decline** — a κ budget beyond the f32 headroom
    ///   (`κ·ε_f32 ≥ 1/4`), which the router prices as un-refinable
    ///   and keeps at [`crate::solver::Precision::Full`].
    ///
    /// Sizes stay test-small (numerics run on host), so the *cost*
    /// crossover routes these Full through the service — the numeric
    /// regimes are exercised by forcing the mixed tier at the solver
    /// layer (`tests` below and `rust/tests/mixed.rs`); the fleet
    /// trace exercises tolerance-carrying SLOs end to end with zero
    /// lost requests.
    pub fn mixed_mix() -> Self {
        let prec = |n, nrhs, dtype, tol, cond, class, budget: Option<u64>, tenant| RequestSpec {
            route: Route::Dist(DistRoutine::Potrs),
            n,
            nrhs,
            dtype,
            class,
            deadline_budget_ns: budget,
            tenant,
            tol: Some(tol),
            cond,
            seed: 0,
        };
        Population::new(vec![
            // Converging f64 refinement: κ=1e3, loose tolerance.
            (
                0.35,
                prec(192, 2, DType::F64, 1e-10, 1e3, SloClass::Interactive, Some(80_000_000), 1),
            ),
            // Converging complex128 refinement.
            (0.20, prec(128, 1, DType::C128, 1e-8, 1e2, SloClass::Standard, None, 2)),
            // Floor bait: tolerance below the f64 floor κ·ε_f64 ≈
            // 2e-12. The router declines it (routed Full through the
            // service); forced Mixed at the solver layer (the tests
            // below) it plateaus, trips the cap/stall check, and
            // recovers through the typed full-precision fallback.
            (0.15, prec(96, 1, DType::F64, 1e-15, 1e4, SloClass::Standard, None, 2)),
            // Router decline: κ=1e9 blows the f32 headroom, so the
            // planner keeps this Full regardless of the predicted win.
            (0.15, prec(256, 1, DType::F64, 1e-6, 1e9, SloClass::Standard, None, 3)),
            // Plain full-precision background work rides along.
            (0.15, RequestSpec {
                route: Route::Dist(DistRoutine::Potrf),
                n: 384,
                nrhs: 0,
                dtype: DType::F32,
                class: SloClass::Batch,
                deadline_budget_ns: None,
                tenant: 3,
                tol: None,
                cond: 1.0,
                seed: 0,
            }),
        ])
    }
}

// ---------------------------------------------------------------------------
// Submission (type-erased completion)
// ---------------------------------------------------------------------------

/// A submitted request whose caller only cares about scheduling
/// outcomes: blocks for completion and yields the [`SolveStats`] (or
/// the typed [`ServeError`]), erasing the solve's result type so mixed
/// dtype/routine traffic collects into one `Vec`.
pub struct Pending {
    wait: Box<dyn FnOnce() -> std::result::Result<SolveStats, ServeError> + Send>,
}

impl Pending {
    /// Wrap any service handle.
    pub fn from_handle<T: Send + 'static>(h: ServiceHandle<T>) -> Self {
        Pending { wait: Box::new(move || h.wait_result().map(|(_, stats)| stats)) }
    }

    /// Block until the request resolves.
    pub fn wait(self) -> std::result::Result<SolveStats, ServeError> {
        (self.wait)()
    }
}

/// Submit one generated request to the SPMD front at simulated time
/// `now_ns` (the arrival instant: deadlines are `now + budget`).
pub fn submit_spec(svc: &SolveService, spec: &RequestSpec, now_ns: u64) -> Result<Pending> {
    match spec.dtype {
        DType::F32 => submit_typed::<f32>(svc, spec, now_ns),
        DType::F64 => submit_typed::<f64>(svc, spec, now_ns),
        DType::C64 => submit_typed::<c32>(svc, spec, now_ns),
        DType::C128 => submit_typed::<c64>(svc, spec, now_ns),
    }
}

fn submit_typed<S: Scalar + MixedCapable>(
    svc: &SolveService,
    spec: &RequestSpec,
    now_ns: u64,
) -> Result<Pending> {
    let slo = spec.slo_at(now_ns);
    // Condition-carrying templates draw an input with that spectrum so
    // the refinement behavior matches what the router was told.
    let a = if spec.cond > 1.0 {
        Matrix::<S>::spd_random_cond(spec.n, spec.seed, spec.cond)
    } else {
        Matrix::<S>::spd_random(spec.n, spec.seed)
    };
    let rhs_seed = spec.seed ^ 0x9E37_79B9_7F4A_7C15;
    match spec.route {
        Route::Small(r) => {
            let rhs = matches!(r, SmallRoutine::Potrs)
                .then(|| Matrix::<S>::random(spec.n, spec.nrhs.max(1), rhs_seed));
            svc.submit_small_slo(r, a, rhs, slo).map(Pending::from_handle)
        }
        Route::Dist(DistRoutine::Syevd) => svc.submit_syevd_slo(a, slo).map(Pending::from_handle),
        Route::Dist(r) => {
            let rhs = matches!(r, DistRoutine::Potrs)
                .then(|| Matrix::<S>::random(spec.n, spec.nrhs.max(1), rhs_seed));
            svc.submit_dist_slo(r, a, rhs, slo).map(Pending::from_handle)
        }
    }
}

// ---------------------------------------------------------------------------
// Open loop
// ---------------------------------------------------------------------------

/// One scheduled arrival of an open-loop trace.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    /// Arrival instant on the simulated timeline.
    pub at_ns: u64,
    /// What arrives.
    pub spec: RequestSpec,
}

/// Open-loop generator: arrivals are scheduled by the
/// [`ArrivalProcess`] regardless of completions, so queues grow when
/// the fleet falls behind — the regime where scheduling policy shows
/// up in tail latency.
#[derive(Clone, Debug)]
pub struct OpenLoop {
    /// Gap distribution.
    pub arrivals: ArrivalProcess,
    /// Request mixture.
    pub population: Population,
    /// Seed for the whole trace.
    pub seed: u64,
    /// Timeline offset of the first gap (ns).
    pub start_ns: u64,
}

impl OpenLoop {
    /// A generator starting at the timeline origin.
    pub fn new(arrivals: ArrivalProcess, population: Population, seed: u64) -> Self {
        OpenLoop { arrivals, population, seed, start_ns: 0 }
    }

    /// Materialize the first `count` arrivals. Deterministic in the
    /// seed; arrival instants are strictly increasing integer ns (the
    /// per-gap float draw is rounded once, floored at 1 ns — the
    /// accumulated timeline itself never re-enters float).
    pub fn trace(&self, count: usize) -> Vec<Arrival> {
        let mut rng = Rng::new(self.seed);
        let mut at_ns = self.start_ns;
        (0..count)
            .map(|_| {
                let gap_s = self.arrivals.next_gap_s(at_ns as f64 * 1e-9, &mut rng);
                let gap_ns = ((gap_s * 1e9).round() as u64).max(1);
                at_ns = at_ns.saturating_add(gap_ns);
                Arrival { at_ns, spec: self.population.sample(&mut rng) }
            })
            .collect()
    }

    /// Generate and submit `count` arrivals against the SPMD front,
    /// pacing the simulated clock to each arrival instant
    /// ([`SimNode::sync_clocks_to_ns`] only moves clocks forward, so a
    /// fleet already past `t` just takes the arrival immediately).
    /// Returns the pending completions in arrival order.
    pub fn drive(&self, node: &SimNode, svc: &SolveService, count: usize) -> Result<Vec<Pending>> {
        let mut out = Vec::with_capacity(count);
        let tracer = node.tracer().clone();
        for arrival in self.trace(count) {
            node.sync_clocks_to_ns(arrival.at_ns);
            // Arrival events are global (the service mints the request's
            // TraceId at submit): the decision log records the traffic
            // shape the spans were generated under.
            if tracer.enabled() {
                tracer.decision(
                    TraceId(0),
                    arrival.at_ns,
                    "arrival",
                    format!(
                        "{:?} n={} dtype={} class={} tenant={}",
                        arrival.spec.route,
                        arrival.spec.n,
                        arrival.spec.dtype.name(),
                        arrival.spec.class.name(),
                        arrival.spec.tenant
                    ),
                );
            }
            out.push(submit_spec(svc, &arrival.spec, node.sim_time_ns())?);
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Closed loop
// ---------------------------------------------------------------------------

/// Closed-loop generator: a fixed window of `concurrency` outstanding
/// requests; each completion (reaped oldest-first) triggers a think
/// pause and one replacement submit. Load self-limits to fleet speed —
/// the throughput-probe counterpart to [`OpenLoop`].
#[derive(Clone, Debug)]
pub struct ClosedLoop {
    /// Request mixture.
    pub population: Population,
    /// Outstanding-request window.
    pub concurrency: usize,
    /// Simulated think time between a completion and its replacement.
    pub think_ns: u64,
    /// Seed for the whole run.
    pub seed: u64,
}

impl ClosedLoop {
    /// Run `total` requests; returns each request's outcome in
    /// submission order.
    pub fn run(
        &self,
        node: &SimNode,
        svc: &SolveService,
        total: usize,
    ) -> Result<Vec<std::result::Result<SolveStats, ServeError>>> {
        let mut rng = Rng::new(self.seed);
        let mut window: VecDeque<Pending> = VecDeque::new();
        let mut results = Vec::with_capacity(total);
        let mut submitted = 0usize;
        let tracer = node.tracer().clone();
        let mut submit_next =
            |rng: &mut Rng, window: &mut VecDeque<Pending>, submitted: &mut usize| -> Result<()> {
                let spec = self.population.sample(rng);
                let now_ns = node.sim_time_ns();
                if tracer.enabled() {
                    tracer.decision(
                        TraceId(0),
                        now_ns,
                        "arrival",
                        format!(
                            "{:?} n={} dtype={} class={} tenant={}",
                            spec.route,
                            spec.n,
                            spec.dtype.name(),
                            spec.class.name(),
                            spec.tenant
                        ),
                    );
                }
                window.push_back(submit_spec(svc, &spec, now_ns)?);
                *submitted += 1;
                Ok(())
            };
        while submitted < total && window.len() < self.concurrency.max(1) {
            submit_next(&mut rng, &mut window, &mut submitted)?;
        }
        while let Some(pending) = window.pop_front() {
            results.push(pending.wait());
            if submitted < total {
                if self.think_ns > 0 {
                    node.sync_clocks_to_ns(node.sim_time_ns().saturating_add(self.think_ns));
                }
                submit_next(&mut rng, &mut window, &mut submitted)?;
            }
        }
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson(rate_hz: f64) -> ArrivalProcess {
        ArrivalProcess::Poisson { rate_hz }
    }

    #[test]
    fn traces_are_deterministic_in_the_seed() {
        let gen = OpenLoop::new(poisson(500.0), Population::gp_vmc_mix(), 42);
        let a = gen.trace(200);
        let b = gen.trace(200);
        assert_eq!(a.len(), 200);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_ns, y.at_ns);
            assert_eq!(x.spec.seed, y.spec.seed);
            assert_eq!(x.spec.n, y.spec.n);
        }
        let other = OpenLoop::new(poisson(500.0), Population::gp_vmc_mix(), 43);
        let c = other.trace(200);
        assert!(a.iter().zip(&c).any(|(x, y)| x.at_ns != y.at_ns || x.spec.seed != y.spec.seed));
    }

    #[test]
    fn arrivals_strictly_increase() {
        for proc in [
            poisson(1e6),
            ArrivalProcess::Diurnal { base_hz: 1e5, peak_hz: 1e7, period_s: 1e-3 },
            ArrivalProcess::Bursty { idle_hz: 1e3, burst_hz: 1e8, burst_prob: 0.5 },
        ] {
            let gen = OpenLoop::new(proc, Population::gp_vmc_mix(), 7);
            let trace = gen.trace(500);
            for w in trace.windows(2) {
                assert!(w[1].at_ns > w[0].at_ns, "arrival instants must strictly increase");
            }
        }
    }

    #[test]
    fn poisson_mean_gap_matches_rate() {
        let gen = OpenLoop::new(poisson(100.0), Population::gp_vmc_mix(), 11);
        let trace = gen.trace(4000);
        let span_s = trace.last().unwrap().at_ns as f64 * 1e-9;
        let mean_gap = span_s / trace.len() as f64;
        assert!(
            (mean_gap - 0.01).abs() < 0.002,
            "mean gap {mean_gap} strays from 10 ms at rate 100 Hz"
        );
    }

    #[test]
    fn diurnal_mean_rate_sits_between_base_and_peak() {
        let count = 4000;
        let diurnal = OpenLoop::new(
            ArrivalProcess::Diurnal { base_hz: 50.0, peak_hz: 500.0, period_s: 2.0 },
            Population::gp_vmc_mix(),
            13,
        );
        let span_s = diurnal.trace(count).last().unwrap().at_ns as f64 * 1e-9;
        let mean_hz = count as f64 / span_s;
        assert!(
            mean_hz > 60.0 && mean_hz < 490.0,
            "diurnal mean rate {mean_hz} Hz should sit between trough and crest"
        );
    }

    #[test]
    fn bursty_gaps_are_bimodal() {
        let gen = OpenLoop::new(
            ArrivalProcess::Bursty { idle_hz: 1.0, burst_hz: 10_000.0, burst_prob: 0.5 },
            Population::gp_vmc_mix(),
            17,
        );
        let trace = gen.trace(2000);
        let mut prev = 0u64;
        let mut short = 0usize;
        for a in &trace {
            // Bursts at 10 kHz give ~0.1 ms gaps; idle at 1 Hz gives ~1 s.
            if a.at_ns - prev < 10_000_000 {
                short += 1;
            }
            prev = a.at_ns;
        }
        let frac = short as f64 / trace.len() as f64;
        assert!((0.35..0.65).contains(&frac), "burst fraction {frac} strays from burst_prob 0.5");
    }

    #[test]
    fn gp_vmc_mix_covers_routes_classes_and_dtypes() {
        let pop = Population::gp_vmc_mix();
        let mut rng = Rng::new(23);
        let mut interactive = 0usize;
        let mut batch = 0usize;
        let mut small = 0usize;
        let mut dist = 0usize;
        let mut dtypes = std::collections::HashSet::new();
        let draws = 2000;
        for _ in 0..draws {
            let s = pop.sample(&mut rng);
            match s.route {
                Route::Small(_) => small += 1,
                Route::Dist(_) => dist += 1,
            }
            match s.class {
                SloClass::Interactive => {
                    interactive += 1;
                    assert!(s.deadline_budget_ns.is_some(), "interactive work carries a deadline");
                }
                SloClass::Batch => batch += 1,
                SloClass::Standard => {}
            }
            dtypes.insert(s.dtype);
        }
        assert!(small > 0 && dist > 0, "both serving paths must appear");
        assert!(batch > 0, "batch-class background work must appear");
        let frac = interactive as f64 / draws as f64;
        assert!((0.35..0.65).contains(&frac), "interactive fraction {frac} strays from 0.5");
        assert!(dtypes.len() >= 3, "the mix spans f32/f64/c128, got {dtypes:?}");
    }

    #[test]
    fn mixed_mix_spans_tolerance_regimes_and_declines_high_kappa() {
        use crate::coordinator::{plan_dist_prec, NumericPolicy};
        use crate::costmodel::GpuCostModel;
        use crate::solver::Precision;
        let pop = Population::mixed_mix();
        let mut tols = 0usize;
        let mut conds = std::collections::HashSet::new();
        for &(_, spec) in pop.entries() {
            if spec.tol.is_some() {
                tols += 1;
                assert!(spec.cond >= 1.0, "tolerance templates declare a κ budget");
            }
            conds.insert(spec.cond.to_bits());
        }
        assert!(tols >= 3, "most templates carry a tolerance");
        assert!(conds.len() >= 3, "condition budgets must spread across regimes");
        // The κ=1e9 budget is beyond the f32 headroom: even at a scale
        // where the mixed tier wins on cost, the router keeps it Full.
        let node = SimNode::new_uniform(8, 1 << 30);
        let model = GpuCostModel::h200();
        let well = plan_dist_prec(
            "potrs",
            16384,
            1,
            1024,
            8,
            DType::F64,
            &model,
            node.topology(),
            None,
            Some(NumericPolicy::new(1e-6, 1e3)),
        )
        .unwrap();
        assert_eq!(well.precision, Precision::Mixed(DType::F32));
        let ill = plan_dist_prec(
            "potrs",
            16384,
            1,
            1024,
            8,
            DType::F64,
            &model,
            node.topology(),
            None,
            Some(NumericPolicy::new(1e-6, 1e9)),
        )
        .unwrap();
        assert_eq!(ill.precision, Precision::Full);
    }

    #[test]
    fn mixed_mix_exercises_convergence_cap_and_fallback() {
        use crate::costmodel::GpuCostModel;
        use crate::layout::BlockCyclic1D;
        use crate::solver::{
            solve_dist_prec, MixedRun, PipelineConfig, Precision, DEFAULT_REFINE_CAP,
        };
        use crate::tile::LayoutKind;
        let node = SimNode::new_uniform(4, 1 << 26);
        let model = GpuCostModel::h200();
        let pop = Population::mixed_mix();
        let mut converged = 0usize;
        let mut fell_back = 0usize;
        for &(_, spec) in pop.entries() {
            let Some(tol) = spec.tol else { continue };
            if spec.dtype != DType::F64 {
                continue;
            }
            // The κ=1e9 template is the router-decline regime; the
            // solver would refuse it the same way, so skip it here.
            if spec.cond * f32::EPSILON as f64 >= 0.25 {
                continue;
            }
            let a = Matrix::<f64>::spd_random_cond(spec.n, 5, spec.cond);
            let b = Matrix::<f64>::random(spec.n, spec.nrhs.max(1), 6);
            let kind = LayoutKind::BlockCyclic(BlockCyclic1D::new(spec.n, 16, 4).unwrap());
            let run = MixedRun::new(&node, &model, PipelineConfig::barrier(), kind);
            let (x, out) = solve_dist_prec::<f64>(
                &run,
                Precision::Mixed(DType::F32),
                &a,
                &b,
                crate::solver::RefineOptions { tol, max_iters: DEFAULT_REFINE_CAP },
            )
            .expect("a routed-Mixed request must always yield a result");
            assert_eq!(x.rows(), spec.n);
            assert!(x.as_slice().iter().all(|v| v.is_finite()));
            if out.fell_back {
                fell_back += 1;
            } else {
                assert!(out.mixed);
                assert!(
                    out.report.residual <= tol,
                    "refined residual {} exceeds tol {tol}",
                    out.report.residual
                );
                converged += 1;
            }
        }
        assert!(converged >= 1, "a converging template must meet its tolerance in mixed");
        assert!(fell_back >= 1, "the stall-bait template must trip the cap and fall back");
    }

    #[test]
    fn mixed_mix_drives_the_service_with_zero_lost_requests() {
        let node = SimNode::new_uniform(2, 1 << 30);
        let svc = SolveService::new(node.clone(), 2);
        let gen = OpenLoop::new(poisson(50_000.0), Population::mixed_mix(), 53);
        let pending = gen.drive(&node, &svc, 8).unwrap();
        for p in pending {
            p.wait().expect("mixed-mix request failed");
        }
        svc.drain();
    }

    #[test]
    fn sampled_seeds_differ_per_request() {
        let pop = Population::gp_vmc_mix();
        let mut rng = Rng::new(29);
        let a = pop.sample(&mut rng);
        let b = pop.sample(&mut rng);
        assert_ne!(a.seed, b.seed, "each draw must get fresh matrix inputs");
    }

    #[test]
    fn fabric_mix_stays_island_confined_on_a_two_island_fabric() {
        // The mix's contract: every distributed template sits below the
        // 1-node-vs-2-node crossover, so the fabric planner confines it
        // to one island's 8-device prefix (both islands serve
        // independently; nothing in fleet traffic crosses the fabric).
        let fab = crate::fabric::Fabric::h200(2);
        let topo = fab.node().topology();
        let model = crate::costmodel::GpuCostModel::h200();
        let pop = Population::fabric_mix();
        let mut tenants = std::collections::HashSet::new();
        for &(_, spec) in pop.entries() {
            tenants.insert(spec.tenant);
            let Route::Dist(r) = spec.route else { continue };
            let plan = crate::coordinator::plan_dist(
                r.name(),
                spec.n,
                spec.nrhs,
                8,
                fab.num_devices(),
                spec.dtype,
                &model,
                topo,
                None,
            )
            .unwrap();
            assert_eq!(
                plan.ndev, 8,
                "{} n={} must confine to one island, planned {:?}",
                r.name(),
                spec.n,
                plan.grid
            );
            assert_eq!(plan.footprint.devices(), 16, "admission must stay node-wide");
            assert!(
                (8..16).all(|d| plan.footprint.bytes(d) == 0),
                "the idle island must reserve nothing"
            );
        }
        assert!(tenants.len() >= 4, "the fabric mix is multi-tenant: {tenants:?}");
    }

    #[test]
    fn zero_churn_reuse_draws_only_hot_seeds() {
        let pop = Population::gp_vmc_mix_reuse(3, 0.0);
        let mut rng = Rng::new(41);
        let mut seeds = std::collections::HashSet::new();
        for _ in 0..300 {
            seeds.insert(pop.sample(&mut rng).seed);
        }
        assert!(seeds.len() <= 3, "hot=3, churn=0 must confine seeds to the pool: {seeds:?}");
        assert!(seeds.len() > 1, "draws should spread over the hot pool");
    }

    #[test]
    fn full_churn_reuse_matches_fresh_sampling_diversity() {
        let pop = Population::gp_vmc_mix_reuse(3, 1.0);
        let mut rng = Rng::new(43);
        let mut seeds = std::collections::HashSet::new();
        let draws = 300;
        for _ in 0..draws {
            seeds.insert(pop.sample(&mut rng).seed);
        }
        assert_eq!(seeds.len(), draws, "churn=1.0 must never repeat a seed");
    }

    #[test]
    fn reuse_traces_are_deterministic_and_mostly_hot() {
        let gen =
            OpenLoop::new(poisson(500.0), Population::gp_vmc_mix_reuse(4, 0.2), 47);
        let a = gen.trace(400);
        let b = gen.trace(400);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.spec.seed, y.spec.seed, "reuse traces must replay under one seed");
        }
        let mut counts: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
        for arr in &a {
            *counts.entry(arr.spec.seed).or_default() += 1;
        }
        let repeated: usize =
            counts.values().filter(|&&c| c > 1).sum();
        let frac = repeated as f64 / a.len() as f64;
        assert!(frac > 0.6, "hot=4, churn=0.2 should make most draws repeats, got {frac}");
    }

    #[test]
    fn deadline_budget_becomes_absolute_at_submit() {
        let spec = RequestSpec {
            route: Route::Dist(DistRoutine::Potrs),
            n: 96,
            nrhs: 1,
            dtype: DType::F64,
            class: SloClass::Interactive,
            deadline_budget_ns: Some(1_000),
            tenant: 1,
            tol: None,
            cond: 1.0,
            seed: 0,
        };
        let slo = spec.slo_at(5_000);
        assert_eq!(slo.deadline_ns, Some(6_000));
        assert_eq!(slo.class, SloClass::Interactive);
        assert_eq!(slo.tenant, 1);
    }

    #[test]
    fn open_loop_drives_the_spmd_front() {
        let node = SimNode::new_uniform(2, 1 << 30);
        let svc = SolveService::new(node.clone(), 2);
        let gen = OpenLoop::new(poisson(50_000.0), Population::gp_vmc_mix(), 31);
        let last_arrival = gen.trace(6).last().unwrap().at_ns;
        let pending = gen.drive(&node, &svc, 6).unwrap();
        svc.flush_small();
        for p in pending {
            let stats = p.wait().expect("open-loop request failed");
            assert!(stats.batch_size >= 1);
        }
        svc.drain();
        assert!(
            node.sim_time_ns() >= last_arrival,
            "pacing must advance the fleet to the last arrival"
        );
    }

    #[test]
    fn closed_loop_completes_the_requested_total() {
        let node = SimNode::new_uniform(2, 1 << 30);
        let svc = SolveService::new(node.clone(), 2);
        let lp = ClosedLoop {
            population: Population::gp_vmc_mix(),
            concurrency: 3,
            think_ns: 1_000,
            seed: 37,
        };
        let results = lp.run(&node, &svc, 8).unwrap();
        svc.drain();
        assert_eq!(results.len(), 8);
        for r in results {
            r.expect("closed-loop request failed");
        }
    }
}
