//! Single-GPU baseline — the paper's comparator.
//!
//! Fig. 3 compares JAXMg against the native single-GPU JAX routines
//! (which call cuSOLVERDn): `cho_factor`+`cho_solve`, `jnp.linalg.inv`,
//! `jnp.linalg.eigh`. This module reproduces that baseline on **one**
//! simulated device: the whole matrix must fit in a single device's
//! VRAM (hence the baseline's early OOM cutoff in the benches — the
//! paper's headline motivation), and all FLOPs are charged to that one
//! timeline (hence the crossover once aggregate multi-GPU throughput
//! wins).

use crate::costmodel::GpuCostModel;
use crate::device::SimNode;
use crate::error::Result;
use crate::linalg::{self, Matrix};
use crate::scalar::Scalar;

/// Single-device dense solver, pinned to `dev` on `node`.
pub struct SingleGpu<'a> {
    node: &'a SimNode,
    dev: usize,
    model: &'a GpuCostModel,
}

impl<'a> SingleGpu<'a> {
    /// Baseline bound to one device.
    pub fn new(node: &'a SimNode, dev: usize, model: &'a GpuCostModel) -> Self {
        SingleGpu { node, dev, model }
    }

    /// Allocate the device-resident working set (errors with OOM when
    /// the matrix no longer fits — the baseline's capacity wall).
    fn alloc_working_set<S: Scalar>(&self, elems: usize) -> Result<crate::device::DevPtr> {
        self.node.alloc_scalars::<S>(self.dev, elems)
    }

    /// `jax.scipy.linalg.cho_factor` + `cho_solve` analogue.
    pub fn potrs<S: Scalar>(&self, a: &Matrix<S>, b: &Matrix<S>) -> Result<Matrix<S>> {
        let n = a.require_square()?;
        let ws = self.alloc_working_set::<S>(n * n + n * b.cols())?;
        // H2D of the operands.
        self.node.charge_h2d(self.dev, (n * n + n * b.cols()) * std::mem::size_of::<S>())?;
        let l = linalg::potrf(a)?;
        self.node.charge_kernel(
            self.dev,
            self.model.panel_time(S::DTYPE, GpuCostModel::flops_potf2(S::DTYPE, n)),
            GpuCostModel::flops_potf2(S::DTYPE, n),
        )?;
        let x = linalg::potrs_from_chol(&l, b)?;
        let fl = GpuCostModel::flops_trsm(S::DTYPE, n, b.cols(), n);
        self.node.charge_kernel(self.dev, self.model.panel_time(S::DTYPE, 2 * fl), 2 * fl)?;
        self.node.free(ws)?;
        Ok(x)
    }

    /// `jax.numpy.linalg.inv` analogue (via Cholesky, SPD input).
    pub fn potri<S: Scalar>(&self, a: &Matrix<S>) -> Result<Matrix<S>> {
        let n = a.require_square()?;
        // inv materializes the inverse out of place: 2 full matrices.
        let ws = self.alloc_working_set::<S>(2 * n * n)?;
        self.node.charge_h2d(self.dev, n * n * std::mem::size_of::<S>())?;
        let l = linalg::potrf(a)?;
        self.node.charge_kernel(
            self.dev,
            self.model.panel_time(S::DTYPE, GpuCostModel::flops_potf2(S::DTYPE, n)),
            GpuCostModel::flops_potf2(S::DTYPE, n),
        )?;
        let inv = linalg::potri_from_chol(&l)?;
        // trtri (n³/3) + lauum (n³/3) at GEMM-ish rate.
        let fl = 2 * GpuCostModel::flops_potf2(S::DTYPE, n);
        self.node
            .charge_kernel(self.dev, self.model.gemm_time(S::DTYPE, n, n, n / 3 + 1), fl)?;
        self.node.free(ws)?;
        Ok(inv)
    }

    /// `jax.numpy.linalg.eigh` analogue.
    pub fn syevd<S: Scalar>(&self, a: &Matrix<S>) -> Result<(Vec<<S as Scalar>::Real>, Matrix<S>)> {
        let n = a.require_square()?;
        // eigh working set: A + V + tridiagonal scratch.
        let ws = self.alloc_working_set::<S>(3 * n * n)?;
        self.node.charge_h2d(self.dev, n * n * std::mem::size_of::<S>())?;
        let eig = linalg::syevd_host(a)?;
        // Tridiagonalization is BLAS-2/HBM-bound: ~8/3 n³ flops over n² data
        // passes; QL + back-transform ~6n³.
        let esize = std::mem::size_of::<S>();
        let bytes = (n * n * esize) as u64;
        self.node.charge_kernel(self.dev, self.model.blas2_time(bytes) * n as f64 / 4.0, (8 * n * n * n / 3) as u64)?;
        self.node.charge_kernel(
            self.dev,
            self.model.gemm_time(S::DTYPE, n, n, n),
            GpuCostModel::flops_gemm(S::DTYPE, n, n, n),
        )?;
        self.node.free(ws)?;
        Ok((eig.values, eig.vectors))
    }

    /// Largest N fitting this baseline for a routine (capacity wall).
    pub fn capacity_n<S: Scalar>(&self, routine: &str) -> usize {
        let vram = self.node.memory_reports()[self.dev].capacity;
        let e = std::mem::size_of::<S>();
        let per_n = |n: usize| match routine {
            "potrs" => (n * n + n) * e,
            "potri" => 2 * n * n * e,
            "syevd" => 3 * n * n * e,
            _ => usize::MAX,
        };
        let mut n = 1usize;
        while per_n(n * 2) <= vram {
            n *= 2;
        }
        let step = (n / 16).max(1);
        while per_n(n + step) <= vram {
            n += step;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::linalg::{tol_for, FrobNorm};
    use crate::scalar::c64;

    fn setup() -> (SimNode, GpuCostModel) {
        (SimNode::new_uniform(1, 1 << 24), GpuCostModel::h200())
    }

    #[test]
    fn baseline_potrs_correct() {
        let (node, model) = setup();
        let bl = SingleGpu::new(&node, 0, &model);
        let a = Matrix::<f64>::spd_random(16, 1);
        let xt = Matrix::<f64>::random(16, 2, 2);
        let b = a.matmul(&xt);
        let x = bl.potrs(&a, &b).unwrap();
        assert!(x.rel_err(&xt) < tol_for::<f64>(16));
        assert!(node.device(0).unwrap().clock().now() > 0.0);
    }

    #[test]
    fn baseline_potri_correct() {
        let (node, model) = setup();
        let bl = SingleGpu::new(&node, 0, &model);
        let a = Matrix::<c64>::spd_random(12, 3);
        let inv = bl.potri(&a).unwrap();
        assert!(a.matmul(&inv).rel_err(&Matrix::eye(12)) < tol_for::<c64>(12));
    }

    #[test]
    fn baseline_syevd_correct() {
        let (node, model) = setup();
        let bl = SingleGpu::new(&node, 0, &model);
        let a = Matrix::<f64>::spd_diag(10);
        let (vals, _vecs) = bl.syevd(&a).unwrap();
        for i in 0..10 {
            assert!((vals[i] - (i + 1) as f64).abs() < 1e-10);
        }
    }

    #[test]
    fn baseline_hits_capacity_wall() {
        // 1 MiB device: a 512×512 f64 matrix (2 MiB) cannot even hold A.
        let node = SimNode::new_uniform(1, 1 << 20);
        let model = GpuCostModel::h200();
        let bl = SingleGpu::new(&node, 0, &model);
        let a = Matrix::<f64>::spd_diag(512);
        let b = Matrix::<f64>::ones(512, 1);
        assert!(matches!(bl.potrs(&a, &b), Err(Error::DeviceOom { .. })));
    }

    #[test]
    fn capacity_ordering_matches_workspace() {
        let (node, model) = setup();
        let bl = SingleGpu::new(&node, 0, &model);
        let potrs = bl.capacity_n::<f64>("potrs");
        let potri = bl.capacity_n::<f64>("potri");
        let syevd = bl.capacity_n::<f64>("syevd");
        assert!(potrs > potri, "{potrs} vs {potri}");
        assert!(potri > syevd, "{potri} vs {syevd}");
    }
}
