"""Repo-root pytest config: make `compile.*` importable when tests are
invoked as `pytest python/tests/` from the repository root (the Makefile
invokes them from `python/`; both must work)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).parent / "python"))
