//! Mixed-precision solve ladder: modeled makespans under the real
//! H200 constants, the router's decision table, and a simulated
//! end-to-end comparison through the SPMD service.
//!
//! Three sections, all deterministic:
//!
//! 1. **Modeled ladder** — [`Predictor::mixed_potrs`] (demotion cast +
//!    working-dtype factor + refinement loop) against the
//!    full-precision [`Predictor::dist_makespan`] replay for f64 potrs
//!    on 8 devices, `tol = 1e-10` at `κ = 1e3`. Asserts the PR's
//!    acceptance bar: the mixed tier wins **≥ 25%** of modeled
//!    makespan at every `N ≥ 16384`.
//! 2. **Decision table** — [`plan_dist_prec`] over a (tol, κ) grid at
//!    N = 16384: Mixed where the replay wins and `κ·ε_f32 < 0.25`,
//!    Full where refinement cannot contract (κ = 1e9), where the
//!    tolerance sits below the attainable f64 residual floor `κ·ε_f64`
//!    (a guaranteed stall the router refuses to price as the cheap
//!    tier), or where the caller states no tolerance. The same table
//!    is documented in `coordinator/admit.rs` and EXPERIMENTS.md.
//! 3. **End-to-end (simulated)** — the identical request stream through
//!    two `SolveService`s on a flop-slowed model (crossover pulled
//!    below test sizes, numerics untouched): one with a tolerance SLO
//!    (routed Mixed, genuinely refines in f32) and one without (Full).
//!    The mixed service must finish in strictly less simulated time and
//!    meet the requested residual.
//!
//! `MIXED_BENCH_SMOKE=1` shrinks the ladder for `make bench-mixed`
//! (CI test mode); the ≥ 25% bar at N = 16384 is asserted in both
//! modes. Results are recorded in EXPERIMENTS.md.

use jaxmg::coordinator::{plan_dist_prec, DistRoutine, NumericPolicy, Slo, SmallConfig, SolveService};
use jaxmg::costmodel::{GpuCostModel, Predictor};
use jaxmg::linalg::Matrix;
use jaxmg::prelude::*;
use jaxmg::scalar::DType;
use jaxmg::solver::Precision;

const NDEV: usize = 8;
const TILE: usize = 1024;
const TOL: f64 = 1e-10;
const COND: f64 = 1e3;

fn h200_predictor(topo: &jaxmg::device::NodeTopology) -> Predictor {
    Predictor { model: GpuCostModel::h200(), topo: topo.clone(), dtype: DType::F64 }
}

fn main() {
    let smoke = std::env::var_os("MIXED_BENCH_SMOKE").is_some();
    let node = SimNode::new_uniform(NDEV, 1 << 30);
    let topo = node.topology();
    let pred = h200_predictor(topo);

    // ---- 1. modeled ladder -------------------------------------------------
    let ladder: &[usize] =
        if smoke { &[8192, 16384] } else { &[4096, 8192, 16384, 32768, 65536] };
    let iters = pred
        .est_refine_iters(TOL, COND)
        .expect("kappa*eps_f32 ~ 1e-4 is well inside the contraction bound");
    println!(
        "== modeled f64 potrs ladder on {NDEV} devices (tile {TILE}, nrhs 1, \
         tol {TOL:.0e} at kappa {COND:.0e} -> {iters} refine iters) ==\n"
    );
    println!(
        "{:>8} {:>6} {:>14} {:>14} {:>12} {:>10}",
        "n", "grid", "full[ms]", "mixed[ms]", "win", "saved[GB]"
    );
    for &n in ladder {
        // The planner's own grid choice for this width, full precision.
        let plan = plan_dist_prec(
            "potrs",
            n,
            1,
            TILE,
            NDEV,
            DType::F64,
            &pred.model,
            topo,
            None,
            None,
        )
        .expect("plan");
        let (p, q) = plan.grid;
        let full = pred.dist_makespan("potrs", n, 1, TILE, p, q);
        let mixed = pred.mixed_potrs(n, TILE, p, q, 1, iters);
        let win = 1.0 - mixed / full;
        // f64 -> f32 halves the factor's bytes; refinement round-trips
        // the RHS (iters + 1) times at the saved width.
        let saved = 4.0 * (n as f64 * n as f64 + n as f64 * (iters + 1) as f64) / 1e9;
        println!(
            "{:>8} {:>6} {:>14.3} {:>14.3} {:>11.1}% {:>10.2}",
            n,
            format!("{p}x{q}"),
            full * 1e3,
            mixed * 1e3,
            win * 100.0,
            saved
        );
        if n >= 16384 {
            assert!(
                win >= 0.25,
                "mixed must win >=25% of modeled makespan at n={n}; got {:.1}%",
                win * 100.0
            );
        }
    }

    // ---- 2. the router's decision table ------------------------------------
    println!("\n== routing at n=16384 (tol, kappa) -> precision ==\n");
    let cases: &[(Option<(f64, f64)>, bool, &str)] = &[
        (Some((1e-6, 1e3)), true, "loose tol, mild kappa"),
        (Some((1e-10, 1e3)), true, "tight tol, mild kappa"),
        (Some((1e-15, 1e4)), false, "tol below the f64 floor kappa*eps_f64: guaranteed stall"),
        (Some((1e-6, 1e9)), false, "kappa*eps >= 0.25: cannot contract"),
        (None, false, "no tolerance stated"),
    ];
    for (numeric, expect_mixed, label) in cases {
        let plan = plan_dist_prec(
            "potrs",
            16384,
            1,
            TILE,
            NDEV,
            DType::F64,
            &pred.model,
            topo,
            None,
            numeric.map(|(t, c)| NumericPolicy::new(t, c)),
        )
        .expect("plan");
        let tag = match plan.precision {
            Precision::Mixed(w) => format!("Mixed({})", w.name()),
            Precision::Full => "Full".to_string(),
        };
        let col = match numeric {
            Some((t, c)) => format!("tol {t:.0e} kappa {c:.0e}"),
            None => "—".to_string(),
        };
        println!("  {col:<24} -> {tag:<12} ({label})");
        assert_eq!(
            plan.precision.is_mixed(),
            *expect_mixed,
            "{label}: expected {}",
            if *expect_mixed { "Mixed" } else { "Full" }
        );
    }

    // ---- 3. simulated end-to-end through the service -----------------------
    // Flop rates cut 1e5x (f64:f32 ratio kept) pull the crossover below
    // n ~ 100 so the real refinement loop runs at test sizes.
    let mut slow = GpuCostModel::h200();
    slow.f64_flops /= 1e5;
    slow.f32_flops /= 1e5;
    let n = if smoke { 128 } else { 256 };
    let reqs = if smoke { 4 } else { 12 };
    let a = Matrix::<f64>::spd_random_cond(n, 0x31ED, COND);
    let b = Matrix::<f64>::random(n, 1, 0x31EE);

    let mut times = [0u64; 2];
    for (i, with_tol) in [false, true].into_iter().enumerate() {
        let node = SimNode::new_uniform(4, 1 << 28);
        let mut cfg = SmallConfig::with_tile(16);
        cfg.model = slow.clone();
        let svc = SolveService::with_small_config(node.clone(), 1, cfg);
        let slo = if with_tol {
            Slo::standard().with_tolerance(TOL, COND)
        } else {
            Slo::standard()
        };
        let pending: Vec<_> = (0..reqs)
            .map(|_| {
                svc.submit_dist_slo(DistRoutine::Potrs, a.clone(), Some(b.clone()), slo)
                    .expect("submit")
            })
            .collect();
        for h in pending {
            let (x, _) = h.wait();
            let res = b.sub(&a.matmul(&x)).norm_fro() / b.norm_fro();
            assert!(res <= TOL, "residual {res} > {TOL}");
        }
        svc.drain();
        times[i] = node.sim_time_ns();
        let m = node.metrics().snapshot();
        if with_tol {
            assert_eq!(m.mixed_solves, reqs as u64, "every SLO request must run mixed");
            assert_eq!(m.mixed_fallbacks, 0);
        } else {
            assert_eq!(m.mixed_solves, 0, "no tolerance, no mixed tier");
        }
    }
    println!(
        "\n== end-to-end (simulated, slowed model): {reqs}x f64 potrs n={n} ==\n\n\
         full {:>10.3} ms | mixed {:>10.3} ms | {:.1}% faster",
        times[0] as f64 * 1e-6,
        times[1] as f64 * 1e-6,
        (1.0 - times[1] as f64 / times[0] as f64) * 100.0
    );
    assert!(
        times[1] < times[0],
        "the mixed service ({} ns) must beat the full one ({} ns)",
        times[1],
        times[0]
    );

    println!("\nmixed bench OK");
}
