//! Fig. 3a: `potrs` float32 — JAXMg vs single-GPU cho_factor+cho_solve.
//!
//! Two sections, as in every fig3 bench (see DESIGN.md §Experiment index):
//!
//! 1. **measured** — the simulator actually executes the distributed
//!    solve at small N (tile sweep, 8 devices) and reports real
//!    wall-clock plus the cost-model projection accumulated by the
//!    per-device clocks.
//! 2. **paper scale** — the analytic predictor replays the same
//!    schedule at the paper's N (up to 524 288) and regenerates the
//!    curve shapes: single-GPU wins small, JAXMg wins large, larger
//!    T_A helps only at large N, baseline ends at its VRAM wall.
//!
//! Run: `cargo bench --bench fig3a_potrs` (or `make bench`).

use jaxmg::coordinator::{ExecMode, JaxMg, Mesh};
use jaxmg::costmodel::Predictor;
use jaxmg::prelude::*;
use jaxmg::scalar::DType;
use std::time::Instant;

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

fn main() {
    println!("== Fig. 3a: potrs float32, 8 devices ==\n");
    println!("-- measured (simulator executes the solve; diag(1..N), b=1) --");
    println!("{:>6} {:>5} {:>12} {:>12} {:>12}", "N", "T_A", "wall[ms]", "proj[ms]", "resid");
    for &n in &[128usize, 256, 512] {
        for &t in &[16usize, 32, 64] {
            if n % t != 0 {
                continue;
            }
            let node = SimNode::new_uniform(8, 1 << 30);
            let ctx = JaxMg::builder()
                .mesh(Mesh::new_1d(node, "x"))
                .tile_size(t)
                .exec_mode(ExecMode::Spmd)
                .build()
                .unwrap();
            let a = Matrix::<f32>::spd_diag(n);
            let b = Matrix::<f32>::ones(n, 1);
            let mut walls = vec![];
            let mut proj = 0.0;
            let mut resid = 0.0f64;
            for _ in 0..3 {
                ctx.reset_accounting();
                let t0 = Instant::now();
                let x = ctx.potrs(&a, &b).unwrap();
                walls.push(t0.elapsed().as_secs_f64() * 1e3);
                proj = ctx.projected_time() * 1e3;
                resid = (0..n)
                    .map(|i| (x[(i, 0)] as f64 - 1.0 / (i + 1) as f64).abs())
                    .fold(0.0, f64::max);
            }
            println!("{n:>6} {t:>5} {:>12.2} {proj:>12.3} {resid:>12.3e}", median(walls));
        }
    }

    println!("\n-- paper scale (analytic schedule replay, 8×H200) --");
    let p = Predictor::h200(8, DType::F32);
    let tiles = [128usize, 256, 512, 1024];
    let vram = 143usize * 1000 * 1000 * 1000;
    let single_wall = p.single_capacity("potrs", vram);
    let dist_wall = p.dist_capacity("potrs", vram, 8, 1024);
    print!("{:>9}", "N");
    for t in tiles {
        print!("  jaxmg T={t:<5}");
    }
    println!("  {:>12}", "single-GPU[s]");
    let mut n = 4096usize;
    while n <= 524288 {
        print!("{n:>9}");
        for t in tiles {
            if n > dist_wall {
                print!("  {:>12}", "OOM");
            } else {
                print!("  {:>12.4}", p.potrs(n, t, 8, 1));
            }
        }
        if n > single_wall {
            println!("  {:>12}", "OOM");
        } else {
            println!("  {:>12.4}", p.single_potrs(n, 1));
        }
        n *= 2;
    }
    println!(
        "\ncapacity walls: single-GPU N≈{single_wall}, jaxmg N≈{dist_wall} \
         (paper: largest solvable N = 524288, >1 TB)"
    );

    // ---- ablation: NVLink vs PCIe interconnect ------------------------
    // The paper's testbed is NVLink-connected; this ablation quantifies
    // how much of the multi-GPU win depends on it (the §2.1 panel
    // broadcasts are the interconnect-sensitive term).
    println!("\n-- ablation: interconnect (potrs f32, T_A=1024, 8 devices) --");
    println!("{:>9} {:>12} {:>12} {:>10}", "N", "NVLink[s]", "PCIe[s]", "slowdown");
    let mut pcie = Predictor::h200(8, DType::F32);
    pcie.topo = jaxmg::device::NodeTopology::pcie_all_to_all(8);
    let mut n = 16384usize;
    while n <= 262144 {
        let nv = p.potrs(n, 1024, 8, 1);
        let pc = pcie.potrs(n, 1024, 8, 1);
        println!("{n:>9} {nv:>12.4} {pc:>12.4} {:>9.2}x", pc / nv);
        n *= 4;
    }
    assert!(
        pcie.potrs(65536, 1024, 8, 1) > p.potrs(65536, 1024, 8, 1),
        "PCIe must be slower than NVLink"
    );

    // Shape assertions — the bench fails loudly if the reproduction drifts.
    let small = (p.potrs(4096, 1024, 8, 1), p.single_potrs(4096, 1));
    let large = (p.potrs(262144, 1024, 8, 1), p.single_potrs(262144, 1));
    assert!(small.1 < small.0, "single GPU must win at N=4096");
    assert!(large.0 < large.1, "JAXMg must win at N=262144");
    assert!(
        p.potrs(262144, 1024, 8, 1) < p.potrs(262144, 128, 8, 1),
        "larger tiles must help at large N"
    );
    assert!(dist_wall >= 2 * single_wall, "aggregate VRAM must extend reach");
    println!("shape checks: crossover ✓  tile-size trend ✓  capacity gain ✓");
}
