//! Fig. 1 / §2.1: the 1D-cyclic redistribution itself, plus the 2D
//! tile-grid extension (§5 future work).
//!
//! Reports, per (N, T_A, devices): the permutation-cycle structure
//! (count, longest cycle, columns moved, cross-device fraction), the
//! measured in-place rotation throughput, and the projected NVLink
//! time. The ablation compares in-place cycles against the
//! out-of-place fallback — the design choice §2.1 motivates. The 2D
//! section at the bottom exercises the tile cycle walk (uniform regrid
//! and 2D shard → 2D cyclic) and the generic 1D↔2D re-tiling path.
//!
//! `REDIST_BENCH_SMOKE=1` shrinks the shapes for `make bench-redist`
//! (CI test mode); the asserted invariants are identical.

use jaxmg::layout::{
    BlockCyclic1D, BlockCyclic2D, ContiguousBlock, ContiguousGrid2D, Redistributor,
};
use jaxmg::linalg::Matrix;
use jaxmg::prelude::*;
use jaxmg::tile::{DistMatrix, Layout1D, LayoutKind};
use std::time::Instant;

fn main() {
    let smoke = std::env::var_os("REDIST_BENCH_SMOKE").is_some();
    println!("== §2.1 redistribution: contiguous → 1D block-cyclic ==\n");
    println!(
        "{:>6} {:>5} {:>4} {:>8} {:>8} {:>8} {:>9} {:>11} {:>10}",
        "N", "T_A", "dev", "cycles", "moved", "x-dev", "wall[ms]", "GiB/s", "proj[ms]"
    );
    for &ndev in &[2usize, 4, 8] {
        for &t in &[16usize, 64, 128] {
            let n = if smoke { 256 } else { 1024 };
            if n % (t * ndev) != 0 {
                continue;
            }
            let rows = if smoke { 256 } else { 1024 }; // one square matrix worth of columns
            let node = SimNode::new_uniform(ndev, 1 << 30);
            let a = Matrix::<f32>::random(rows, n, 42);
            let contig = Layout1D::Contiguous(ContiguousBlock::new(n, ndev).unwrap());
            let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, t, ndev).unwrap());
            let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
            node.reset_accounting();
            let t0 = Instant::now();
            let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
            let wall = t0.elapsed().as_secs_f64();
            let bytes = plan.columns_moved * rows * 4;
            let longest = plan.columns_moved.max(1);
            println!(
                "{n:>6} {t:>5} {ndev:>4} {:>8} {:>8} {:>8} {:>9.2} {:>11.2} {:>10.3}",
                plan.nontrivial_cycles,
                plan.columns_moved,
                plan.columns_cross_device,
                wall * 1e3,
                bytes as f64 / wall / (1 << 30) as f64,
                node.sim_time() * 1e3
            );
            let _ = longest;
            assert!(plan.in_place, "balanced shapes must use the in-place path");
            // Verify content after the move.
            assert_eq!(dm.gather().unwrap(), a);
        }
    }

    // ---- ablation: in-place cycles vs out-of-place fallback ----------
    println!("\n-- ablation: in-place (2 staging cols) vs out-of-place (full copy) --");
    println!("{:>6} {:>5} {:>4} {:>12} {:>14} {:>14}", "N", "T_A", "dev", "path", "wall[ms]", "extra VRAM");
    for &(n, t, ndev) in &[(1024usize, 64usize, 4usize), (1000, 64, 4)] {
        let rows = 512;
        let node = SimNode::new_uniform(ndev, 1 << 30);
        let a = Matrix::<f32>::random(rows, n, 7);
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, ndev).unwrap());
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, t, ndev).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        let used_before: usize = node.memory_reports().iter().map(|r| r.peak_used).sum();
        let t0 = Instant::now();
        let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
        let wall = t0.elapsed().as_secs_f64() * 1e3;
        let peak_after: usize = node.memory_reports().iter().map(|r| r.peak_used).sum();
        println!(
            "{n:>6} {t:>5} {ndev:>4} {:>12} {wall:>14.2} {:>11} B",
            if plan.in_place { "in-place" } else { "out-of-place" },
            peak_after - used_before
        );
        assert_eq!(dm.gather().unwrap(), a);
    }
    println!("\n(in-place peak overhead = 2 staging columns; out-of-place = a full second panel set)");

    // ---- 2D tile-grid redistribution (§5 future work) ----------------
    println!("\n== 2D tile grid: tile cycles + 1D↔2D re-tiling ==\n");
    println!(
        "{:>22} {:>6} {:>6} {:>8} {:>8} {:>8} {:>12} {:>9}",
        "conversion", "N", "tile", "cycles", "tiles", "x-dev", "path", "wall[ms]"
    );
    let n2 = if smoke { 256 } else { 1024 };
    let tile = if smoke { 32 } else { 64 };
    let node = SimNode::new_uniform(4, 1 << 30);
    let a = Matrix::<f32>::random(n2, n2, 99);

    let shard2d = LayoutKind::GridContig(ContiguousGrid2D::new(n2, n2, tile, tile, 2, 2).unwrap());
    let grid22 = LayoutKind::Grid(BlockCyclic2D::new(n2, n2, tile, tile, 2, 2).unwrap());
    let grid41 = LayoutKind::Grid(BlockCyclic2D::new(n2, n2, tile, tile, 4, 1).unwrap());
    let cyc1d = LayoutKind::BlockCyclic(BlockCyclic1D::new(n2, tile, 4).unwrap());

    let mut dm = DistMatrix::scatter(&node, &a, shard2d).unwrap();
    for (label, target, expect_in_place) in [
        ("2D shard → 2D cyclic", grid22, true),
        ("2×2 → 4×1 regrid", grid41, true),
        ("4×1 → 2×2 regrid", grid22, true),
        ("2D → 1D re-tiling", cyc1d, false),
        ("1D → 2D re-tiling", grid22, false),
    ] {
        let t0 = Instant::now();
        let plan = Redistributor::convert(&mut dm, target).unwrap();
        let wall = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "{label:>22} {n2:>6} {tile:>6} {:>8} {:>8} {:>8} {:>12} {wall:>9.2}",
            plan.nontrivial_cycles,
            plan.tiles_moved,
            plan.tiles_cross_device,
            if plan.in_place { "in-place" } else { "out-of-place" },
        );
        assert_eq!(
            plan.in_place, expect_in_place,
            "{label}: expected in_place={expect_in_place}"
        );
        assert_eq!(dm.gather().unwrap(), a, "{label} corrupted content");
    }
    println!("\n(tile cycles rotate whole contiguous tiles through 2 tile-sized staging buffers;");
    println!(" re-tilings move per-column tile-row segments out of place)");
}
