//! Fig. 1 / §2.1: the 1D-cyclic redistribution itself.
//!
//! Reports, per (N, T_A, devices): the permutation-cycle structure
//! (count, longest cycle, columns moved, cross-device fraction), the
//! measured in-place rotation throughput, and the projected NVLink
//! time. The ablation at the bottom compares in-place cycles against
//! the out-of-place fallback — the design choice §2.1 motivates.

use jaxmg::layout::{BlockCyclic1D, ContiguousBlock, Redistributor};
use jaxmg::linalg::Matrix;
use jaxmg::prelude::*;
use jaxmg::tile::{DistMatrix, Layout1D};
use std::time::Instant;

fn main() {
    println!("== §2.1 redistribution: contiguous → 1D block-cyclic ==\n");
    println!(
        "{:>6} {:>5} {:>4} {:>8} {:>8} {:>8} {:>9} {:>11} {:>10}",
        "N", "T_A", "dev", "cycles", "moved", "x-dev", "wall[ms]", "GiB/s", "proj[ms]"
    );
    for &ndev in &[2usize, 4, 8] {
        for &t in &[16usize, 64, 128] {
            let n = 1024;
            if n % (t * ndev) != 0 {
                continue;
            }
            let rows = 1024; // one square matrix worth of columns
            let node = SimNode::new_uniform(ndev, 1 << 30);
            let a = Matrix::<f32>::random(rows, n, 42);
            let contig = Layout1D::Contiguous(ContiguousBlock::new(n, ndev).unwrap());
            let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, t, ndev).unwrap());
            let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
            node.reset_accounting();
            let t0 = Instant::now();
            let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
            let wall = t0.elapsed().as_secs_f64();
            let bytes = plan.columns_moved * rows * 4;
            let longest = plan.columns_moved.max(1);
            println!(
                "{n:>6} {t:>5} {ndev:>4} {:>8} {:>8} {:>8} {:>9.2} {:>11.2} {:>10.3}",
                plan.nontrivial_cycles,
                plan.columns_moved,
                plan.columns_cross_device,
                wall * 1e3,
                bytes as f64 / wall / (1 << 30) as f64,
                node.sim_time() * 1e3
            );
            let _ = longest;
            assert!(plan.in_place, "balanced shapes must use the in-place path");
            // Verify content after the move.
            assert_eq!(dm.gather().unwrap(), a);
        }
    }

    // ---- ablation: in-place cycles vs out-of-place fallback ----------
    println!("\n-- ablation: in-place (2 staging cols) vs out-of-place (full copy) --");
    println!("{:>6} {:>5} {:>4} {:>12} {:>14} {:>14}", "N", "T_A", "dev", "path", "wall[ms]", "extra VRAM");
    for &(n, t, ndev) in &[(1024usize, 64usize, 4usize), (1000, 64, 4)] {
        let rows = 512;
        let node = SimNode::new_uniform(ndev, 1 << 30);
        let a = Matrix::<f32>::random(rows, n, 7);
        let contig = Layout1D::Contiguous(ContiguousBlock::new(n, ndev).unwrap());
        let cyclic = Layout1D::BlockCyclic(BlockCyclic1D::new(n, t, ndev).unwrap());
        let mut dm = DistMatrix::scatter(&node, &a, contig).unwrap();
        let used_before: usize = node.memory_reports().iter().map(|r| r.peak_used).sum();
        let t0 = Instant::now();
        let plan = Redistributor::convert(&mut dm, cyclic).unwrap();
        let wall = t0.elapsed().as_secs_f64() * 1e3;
        let peak_after: usize = node.memory_reports().iter().map(|r| r.peak_used).sum();
        println!(
            "{n:>6} {t:>5} {ndev:>4} {:>12} {wall:>14.2} {:>11} B",
            if plan.in_place { "in-place" } else { "out-of-place" },
            peak_after - used_before
        );
        assert_eq!(dm.gather().unwrap(), a);
    }
    println!("\n(in-place peak overhead = 2 staging columns; out-of-place = a full second panel set)");
}
