//! Runtime-layer micro-bench: per-call overhead of (1) the concurrent
//! solve service and (2) the AOT XLA path.
//!
//! Section 1 needs nothing beyond the crate: it measures the queue +
//! admission + handle overhead of `SolveService` against calling the
//! same solve directly — the number that decides how small a solve can
//! be before the service layer stops being free.
//!
//! Section 2 measures the PJRT execute round-trip for each tile kernel
//! (load is cached; the steady-state cost is literal creation + execute
//! + readback) against the native backend's pure-Rust compute, at the
//! artifact tile sizes. This is the ratio the §Perf optimization pass
//! tracks: it determines the tile size at which the AOT path amortizes.
//! It requires `make artifacts` and is skipped otherwise.

use jaxmg::coordinator::{Footprint, SolveService};
use jaxmg::costmodel::GpuCostModel;
use jaxmg::device::SimNode;
use jaxmg::layout::BlockCyclic1D;
use jaxmg::linalg::Matrix;
use jaxmg::runtime::{PjRtRuntime, XlaKernels};
use jaxmg::scalar::DType;
use jaxmg::solver::{potrf_dist, Ctx, NativeKernels, SolverBackend, TileKernels};
use jaxmg::tile::{DistMatrix, Layout1D};
use std::sync::Arc;
use std::time::Instant;

fn bench<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    // Warm-up then median of `reps`.
    f();
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[reps / 2]
}

fn one_potrf(node: &SimNode, n: usize, tile: usize, seed: u64) {
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;
    let ctx = Ctx::pipelined(node, &model, &backend);
    let a = Matrix::<f64>::spd_random(n, seed);
    let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, node.num_devices()).unwrap());
    let mut dm = DistMatrix::scatter(node, &a, lay).unwrap();
    potrf_dist(&ctx, &mut dm).unwrap();
    dm.free().unwrap();
}

fn service_overhead_section() {
    println!("== solve-service overhead: direct call vs submit+wait (f64 potrf) ==\n");
    println!("{:>6} {:>14} {:>14} {:>12}", "N", "direct[µs]", "service[µs]", "overhead");
    let ndev = 4;
    for &n in &[16usize, 64, 128] {
        let tile = (n / 8).max(1);
        let node = SimNode::new_uniform(ndev, 1 << 28);
        let direct = bench(|| one_potrf(&node, n, tile, 1), 10);

        let svc = SolveService::new(node.clone(), 2);
        let fp = Footprint::for_routine("potrf", n, 0, tile, ndev, DType::F64).unwrap();
        let via_service = bench(
            || {
                let node2 = node.clone();
                let h = svc
                    .submit(fp.clone(), move || one_potrf(&node2, n, tile, 1))
                    .unwrap();
                let _ = h.wait();
            },
            10,
        );
        println!(
            "{n:>6} {:>14.1} {:>14.1} {:>11.1}%",
            direct * 1e6,
            via_service * 1e6,
            (via_service / direct - 1.0) * 100.0
        );
    }
    println!();
}

fn aot_section() {
    if !std::path::Path::new("artifacts/.stamp").exists() {
        println!("== AOT overhead section skipped: artifacts/ missing (run `make artifacts`) ==");
        return;
    }
    let rt = Arc::new(PjRtRuntime::new("artifacts").unwrap());
    println!("== runtime overhead: AOT XLA kernels vs native (f64) ==\n");
    println!(
        "{:>6} {:>12} {:>14} {:>14} {:>8}",
        "T", "op", "native[µs]", "xla-aot[µs]", "ratio"
    );
    for &t in &[8usize, 32, 64] {
        let xk = match XlaKernels::<f64>::new(rt.clone(), t) {
            Ok(k) => k,
            Err(_) => continue, // tile size not lowered
        };
        let nk = NativeKernels;
        let a = Matrix::<f64>::spd_random(t, 1);
        let c0 = Matrix::<f64>::random(t, t, 2);
        let b0 = Matrix::<f64>::random(t, t, 3);

        let nat_potf2 = bench(|| { TileKernels::<f64>::potf2(&nk, &a).unwrap(); }, 20);
        let xla_potf2 = bench(|| { TileKernels::<f64>::potf2(&xk, &a).unwrap(); }, 20);
        println!(
            "{t:>6} {:>12} {:>14.1} {:>14.1} {:>8.2}",
            "potf2",
            nat_potf2 * 1e6,
            xla_potf2 * 1e6,
            xla_potf2 / nat_potf2
        );

        let nat_gemm = bench(
            || {
                let mut c = c0.clone();
                nk.gemm_nn(&mut c, &b0, &b0, -1.0).unwrap();
            },
            20,
        );
        let xla_gemm = bench(
            || {
                let mut c = c0.clone();
                xk.gemm_nn(&mut c, &b0, &b0, -1.0).unwrap();
            },
            20,
        );
        println!(
            "{t:>6} {:>12} {:>14.1} {:>14.1} {:>8.2}",
            "gemm_nn",
            nat_gemm * 1e6,
            xla_gemm * 1e6,
            xla_gemm / nat_gemm
        );
    }
    println!(
        "\nexecutables cached: {} (compile-once is what keeps the AOT path viable)",
        rt.cached()
    );
}

fn main() {
    service_overhead_section();
    aot_section();
}
