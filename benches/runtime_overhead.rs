//! Runtime-layer micro-bench: per-call overhead of the AOT path.
//!
//! Measures the PJRT execute round-trip for each tile kernel (load is
//! cached; the steady-state cost is literal creation + execute +
//! readback) against the native backend's pure-Rust compute, at the
//! artifact tile sizes. This is the ratio the §Perf optimization pass
//! tracks: it determines the tile size at which the AOT path amortizes.
//!
//! Requires `make artifacts`.

use jaxmg::linalg::Matrix;
use jaxmg::runtime::{PjRtRuntime, XlaKernels};
use jaxmg::solver::{NativeKernels, TileKernels};
use std::sync::Arc;
use std::time::Instant;

fn bench<F: FnMut()>(mut f: F, reps: usize) -> f64 {
    // Warm-up then median of `reps`.
    f();
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[reps / 2]
}

fn main() {
    if !std::path::Path::new("artifacts/.stamp").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let rt = Arc::new(PjRtRuntime::new("artifacts").unwrap());
    println!("== runtime overhead: AOT XLA kernels vs native (f64) ==\n");
    println!(
        "{:>6} {:>12} {:>14} {:>14} {:>8}",
        "T", "op", "native[µs]", "xla-aot[µs]", "ratio"
    );
    for &t in &[8usize, 32, 64] {
        let xk = match XlaKernels::<f64>::new(rt.clone(), t) {
            Ok(k) => k,
            Err(_) => continue, // tile size not lowered
        };
        let nk = NativeKernels;
        let a = Matrix::<f64>::spd_random(t, 1);
        let c0 = Matrix::<f64>::random(t, t, 2);
        let b0 = Matrix::<f64>::random(t, t, 3);

        let nat_potf2 = bench(|| { TileKernels::<f64>::potf2(&nk, &a).unwrap(); }, 20);
        let xla_potf2 = bench(|| { TileKernels::<f64>::potf2(&xk, &a).unwrap(); }, 20);
        println!(
            "{t:>6} {:>12} {:>14.1} {:>14.1} {:>8.2}",
            "potf2",
            nat_potf2 * 1e6,
            xla_potf2 * 1e6,
            xla_potf2 / nat_potf2
        );

        let nat_gemm = bench(
            || {
                let mut c = c0.clone();
                nk.gemm_nn(&mut c, &b0, &b0, -1.0).unwrap();
            },
            20,
        );
        let xla_gemm = bench(
            || {
                let mut c = c0.clone();
                xk.gemm_nn(&mut c, &b0, &b0, -1.0).unwrap();
            },
            20,
        );
        println!(
            "{t:>6} {:>12} {:>14.1} {:>14.1} {:>8.2}",
            "gemm_nn",
            nat_gemm * 1e6,
            xla_gemm * 1e6,
            xla_gemm / nat_gemm
        );
    }
    println!(
        "\nexecutables cached: {} (compile-once is what keeps the AOT path viable)",
        rt.cached()
    );
}
