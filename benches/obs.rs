//! Observability overhead + drift-correction payoff.
//!
//! Two sections, both on integer-ns simulated clocks:
//!
//! 1. **Passivity** — the identical SPMD fleet trace replayed through
//!    a tracer-off and a tracer-on service. The simulated makespans
//!    must be **bitwise equal** (the tracer charges zero cost-model
//!    nanoseconds) and the tracer-on wall time must stay within a
//!    generous constant factor of the tracer-off wall time (bounded
//!    host overhead — span records are plain pushes under a mutex).
//! 2. **Drift correction** — a repeat-`potrs` stream through a
//!    lookahead-pipelined MPMD front, once with raw Predictor queue
//!    estimates and once with [`MpmdConfig::drift_correction`] on.
//!    The barrier-modeled estimate systematically overshoots the
//!    pipelined execution; after `min_samples` the corrected estimates
//!    lock onto the observed makespan, so the accumulated
//!    `|observed - used-estimate|` error must shrink strictly.
//!
//! `OBS_BENCH_SMOKE=1` shrinks the trace and repeat counts for
//! `make bench-obs` (CI test mode); every asserted invariant is
//! identical. Results are recorded in EXPERIMENTS.md.

use jaxmg::coordinator::{SmallConfig, SolveService};
use jaxmg::linalg::Matrix;
use jaxmg::prelude::*;
use jaxmg::solver::PipelineConfig;
use jaxmg::workload::{submit_spec, OpenLoop, Population};
use std::time::Instant;

const NDEV: usize = 4;
const TILE: usize = 16;
const SEED: u64 = 2027;

fn main() {
    let smoke = std::env::var_os("OBS_BENCH_SMOKE").is_some();

    // ---- 1. passivity: tracing on vs off, bitwise ---------------------------
    let count = if smoke { 32 } else { 128 };
    let trace = OpenLoop::new(
        ArrivalProcess::Poisson { rate_hz: 50.0 },
        Population::gp_vmc_mix_reuse(4, 0.10),
        SEED,
    )
    .trace(count);

    let mut sim_ns = [0u64; 2];
    let mut wall_s = [0f64; 2];
    let mut span_count = [0usize; 2];
    for (i, tracing) in [false, true].into_iter().enumerate() {
        let node = SimNode::new_uniform(NDEV, 1 << 28);
        if tracing {
            node.tracer().enable();
        }
        let svc = SolveService::with_small_config(node.clone(), 1, SmallConfig::with_tile(TILE));
        let wall = Instant::now();
        // Replay the identical arrivals back-to-back — no clock pacing.
        let pending: Vec<_> = trace
            .iter()
            .map(|arr| submit_spec(&svc, &arr.spec, node.sim_time_ns()).expect("trace submit"))
            .collect();
        svc.flush_small();
        for p in pending {
            p.wait().expect("trace request failed");
        }
        svc.drain();
        wall_s[i] = wall.elapsed().as_secs_f64();
        sim_ns[i] = node.sim_time_ns();
        span_count[i] = node.tracer().spans().len();
    }
    println!(
        "== passivity: {count} arrivals of gp_vmc_mix_reuse(hot=4, churn=0.10) ==\n\n\
         tracer off {:>10.3} ms sim, {:>7.1} ms wall, {:>6} spans\n\
         tracer on  {:>10.3} ms sim, {:>7.1} ms wall, {:>6} spans",
        sim_ns[0] as f64 * 1e-6,
        wall_s[0] * 1e3,
        span_count[0],
        sim_ns[1] as f64 * 1e-6,
        wall_s[1] * 1e3,
        span_count[1],
    );
    assert_eq!(
        sim_ns[0], sim_ns[1],
        "tracing must charge zero cost-model ns — makespans diverged"
    );
    assert_eq!(span_count[0], 0, "a disabled tracer must record nothing");
    assert!(span_count[1] > 0, "an enabled tracer must record spans");
    assert!(
        wall_s[1] < wall_s[0] * 20.0 + 0.25,
        "tracing host overhead out of bounds: {:.3}s on vs {:.3}s off",
        wall_s[1],
        wall_s[0]
    );

    // ---- 2. drift correction on a lookahead reuse stream --------------------
    let n = if smoke { 128 } else { 256 };
    let reps = if smoke { 8 } else { 16 };
    let a = Matrix::<f64>::spd_random(n, SEED + 3);
    let b = Matrix::<f64>::random(n, 1, SEED + 5);

    // (total |obs - est_used| ns, total |obs - est_model| ns, samples)
    let run_arm = |correction: bool| -> (u128, u128, u64) {
        let node = SimNode::new_uniform(NDEV, 1 << 28);
        let mut cfg = MpmdConfig::with_tile(32);
        cfg.pipeline = PipelineConfig::lookahead(2);
        cfg.drift_correction = correction;
        let svc = MpmdService::with_config(node.clone(), cfg);
        svc.tracer().enable();
        // Serial submit -> wait: every solve re-plans (no factor cache),
        // so each repeat contributes one drift sample for the same
        // (routine, dtype, n, grid) key.
        for _ in 0..reps {
            let _ = svc.submit_potrs(a.clone(), b.clone()).expect("potrs").wait();
        }
        svc.drain();
        let d = svc.tracer().drift();
        let samples: u64 = d.stats().iter().map(|(_, st)| st.samples).sum();
        (d.total_abs_err_used(), d.total_abs_err_model(), samples)
    };

    let (err_off, model_off, samples_off) = run_arm(false);
    let (err_on, model_on, samples_on) = run_arm(true);
    println!(
        "\n== drift correction: {reps}x lookahead potrs at n={n} ==\n\n\
         correction off: sum|obs-est| {:>12} ns over {samples_off} samples\n\
         correction on:  sum|obs-est| {:>12} ns over {samples_on} samples \
         (model error unchanged: {})",
        err_off,
        err_on,
        model_on == model_off,
    );
    assert_eq!(samples_off, samples_on, "both arms must record the same sample count");
    assert_eq!(
        model_off, model_on,
        "correction must not touch the raw model-drift accounting"
    );
    assert!(
        err_off > 0,
        "the barrier-modeled estimate must drift on a pipelined schedule"
    );
    assert!(
        err_on < err_off,
        "drift correction must tighten the queue estimates: {err_on} !< {err_off}"
    );

    println!("\nobs bench OK");
}
