//! The multi-node fabric bench: hierarchical ring-of-rings collectives
//! vs flat dispatch on a 2×8 two-tier fabric, and the 1-node-vs-2-node
//! routing crossover end-to-end through the planner and the service.
//!
//! Three sections, each asserting the invariants it prints:
//!
//! 1. **ring-of-rings vs flat dispatch** — the same grid-native potrf
//!    under hierarchical and `with_flat_collectives()` pricing:
//!    bitwise-identical factors, and the latency→payload crossover —
//!    flat's fan-out-amortized inter latency wins tiny rings, the
//!    hierarchical O(islands) payload discipline wins once rings carry
//!    real bytes (strictly, at the pinned top rung).
//! 2. **planner routing** — `plan_dist` on the fabric topology: small
//!    and mid shapes confine to one island's device prefix (narrow
//!    plan, zero-byte admission on the idle island), shapes past the
//!    crossover span both islands on an island-aligned grid.
//! 3. **island-confined serving** — a `SolveService` on the 16-device
//!    fabric routes a small potrs onto one 8-device island and returns
//!    bitwise the answer an 8-device single-node service computes.
//!
//! `FABRIC_BENCH_SMOKE=1` shrinks the shapes for `make bench-fabric`
//! (CI test mode); every asserted invariant is identical.

use jaxmg::coordinator::{plan_dist, DistRoutine, SmallConfig, SolveService};
use jaxmg::costmodel::{GpuCostModel, Predictor};
use jaxmg::prelude::*;
use jaxmg::scalar::DType;
use jaxmg::solver::{potrf_dist, Ctx};
use jaxmg::tile::{DistMatrix, LayoutKind};

fn main() {
    let smoke = std::env::var_os("FABRIC_BENCH_SMOKE").is_some();
    let model = GpuCostModel::h200();

    // ---- 1. ring-of-rings vs flat dispatch ----------------------------
    println!("== hierarchical vs flat collectives: grid potrf (8x2, tile 256), 2x8 fabric ==\n");
    println!(
        "{:>8} {:>6} {:>14} {:>14} {:>8} {:>12} {:>12} {:>8}",
        "N", "tile", "hier[µs]", "flat[µs]", "win[%]", "inter[KiB]", "intra[KiB]", "bcasts"
    );
    let (p, q, tile) = (8usize, 2usize, 256usize);
    let ladder: &[usize] = if smoke { &[2048] } else { &[2048, 4096] };
    let top = *ladder.last().unwrap();
    for &n in ladder {
        let run = |flat: bool| -> (Matrix<f64>, f64, u64, u64, u64) {
            let fab = Fabric::h200(2);
            let node = fab.node();
            let backend = SolverBackend::<f64>::Native;
            let a = Matrix::<f64>::spd_random(n, 0xFAB + n as u64);
            let lay = LayoutKind::Grid(BlockCyclic2D::new(n, n, tile, tile, p, q).unwrap());
            let mut dm = DistMatrix::scatter(node, &a, lay).unwrap();
            node.reset_accounting();
            let mut ctx =
                Ctx::with_pipeline(node, &model, &backend, PipelineConfig::lookahead(2));
            if flat {
                ctx = ctx.with_flat_collectives();
            }
            potrf_dist(&ctx, &mut dm).unwrap();
            let t = node.sim_time();
            let m = node.metrics().snapshot();
            (dm.gather().unwrap(), t, m.fabric_inter_bytes, m.fabric_intra_bytes, m.fabric_bcasts)
        };
        let (l_hier, t_hier, inter, intra, bcasts) = run(false);
        let (l_flat, t_flat, _, _, flat_bcasts) = run(true);
        println!(
            "{n:>8} {tile:>6} {:>14.1} {:>14.1} {:>8.2} {:>12.1} {:>12.1} {bcasts:>8}",
            t_hier * 1e6,
            t_flat * 1e6,
            (1.0 - t_hier / t_flat) * 100.0,
            inter as f64 / 1024.0,
            intra as f64 / 1024.0,
        );
        assert_eq!(
            l_hier.as_slice(),
            l_flat.as_slice(),
            "collective dispatch changed numerics at n={n}"
        );
        assert!(inter > 0 && intra > 0 && bcasts > 0, "hierarchical rings must be staged");
        assert_eq!(flat_bcasts, 0, "flat dispatch staged a hierarchical bcast");
        if n == top {
            assert!(
                t_hier < t_flat,
                "hierarchical {t_hier} !< flat {t_flat} at the payload-bound rung n={n}"
            );
        }
    }
    println!("\n(tiny rings are latency-bound — flat's fan-out-amortized inter latency wins;");
    println!(" fat rings are payload-bound — one fabric crossing per island wins decisively)");

    // ---- 2. planner routing: 1 node vs 2 nodes ------------------------
    println!("\n== fabric routing: plan_dist on the 2x8 topology (f64) ==\n");
    println!(
        "{:>8} {:>8} {:>6} {:>8} {:>8} {:>12}",
        "routine", "N", "tile", "devices", "grid", "est[ms]"
    );
    let fab = Fabric::h200(2);
    let topo = fab.node().topology();
    let ndev = fab.num_devices();
    let route = |routine: &str, n: usize, nrhs: usize, tile: usize| -> (usize, (usize, usize)) {
        let plan = plan_dist(routine, n, nrhs, tile, ndev, DType::F64, &model, topo, None).unwrap();
        println!(
            "{routine:>8} {n:>8} {tile:>6} {:>8} {:>5}x{:<2} {:>12.3}",
            plan.ndev,
            plan.grid.0,
            plan.grid.1,
            plan.est_ns as f64 / 1e6
        );
        // Narrow plans still admit node-wide: zero bytes on the idle island.
        assert_eq!(plan.footprint.devices(), ndev, "footprint must stay node-wide");
        if plan.ndev < ndev {
            for d in plan.ndev..ndev {
                assert_eq!(plan.footprint.bytes(d), 0, "idle island must reserve nothing");
            }
        }
        (plan.ndev, plan.grid)
    };
    let (d0, g0) = route("potrs", 96, 1, 8);
    assert_eq!((d0, g0), (8, (1, 8)), "small potrs must confine to one island, 1D");
    let (d1, _) = route("potrf", 16384, 0, 1024);
    assert_eq!(d1, 8, "mid potrf must confine to one island");
    let (d2, g2) = route("potrf", 65536, 0, 1024);
    assert_eq!(d2, 16, "large potrf must span the fabric");
    assert_eq!(g2.0 * g2.1, 16);
    let (d3, _) = route("syevd", 4096, 0, 256);
    assert_eq!(d3, 16, "syevd's bandwidth-hungry sweeps span early");
    // The crossover is the predictor's own strict win, not a tie-break.
    let pf = Predictor { model: model.clone(), topo: topo.clone(), dtype: DType::F64 };
    let island: Vec<usize> = (0..8).collect();
    let sub = Predictor {
        model: model.clone(),
        topo: topo.subset(&island).unwrap(),
        dtype: DType::F64,
    };
    let (full, confined) = (
        pf.dist_makespan("potrf", 65536, 0, 1024, g2.0, g2.1),
        sub.dist_makespan("potrf", 65536, 0, 1024, 4, 2),
    );
    println!("\nspanning 65536: fabric {:.1} ms vs best island {:.1} ms", full * 1e3, confined * 1e3);
    assert!(full < confined, "the spanning plan must be a strict predictor win");

    // ---- 3. island-confined serving -----------------------------------
    println!("\n== island-confined serving: 16-device fabric vs one 8-device node ==\n");
    let (sn, stile) = (96usize, 8usize);
    let sa = Matrix::<f64>::spd_random(sn, 7);
    let sb = Matrix::<f64>::random(sn, 1, 8);
    let run_svc = |node: SimNode| -> (Matrix<f64>, (usize, usize)) {
        let svc = SolveService::with_small_config(node, 2, SmallConfig::with_tile(stile));
        let (x, stats) =
            svc.submit_dist(DistRoutine::Potrs, sa.clone(), Some(sb.clone())).unwrap().wait();
        svc.drain();
        (x, stats.grid)
    };
    let (x_fab, g_fab) = run_svc(fab.node().clone());
    let (x_one, g_one) = run_svc(SimNode::new_uniform(8, 1 << 28));
    println!("fabric-routed grid {g_fab:?}   single-island grid {g_one:?}   bitwise-equal: true");
    assert_eq!(g_fab, (1, 8), "the fabric service must confine the small solve to one island");
    assert_eq!(g_one, (1, 8));
    assert_eq!(x_fab.as_slice(), x_one.as_slice(), "island confinement changed numerics");

    println!("\nfabric bench OK");
}
