//! Repeated-solve throughput: cold factorization vs resident-factor
//! hits vs the fused solve DAG.
//!
//! Three sections, all on integer-ns simulated clocks:
//!
//! 1. **Hit ladder** — the same `potrs` resubmitted against one SPD
//!    matrix, once through a cache-off service (every repeat pays
//!    scatter + potrf) and once through a warmed cache-on service
//!    (every repeat runs only the triangular stages on the resident
//!    shards). Requests are submitted directly — never through
//!    [`OpenLoop::drive`], whose arrival pacing would advance the
//!    clocks to the trace gaps and bury the compute ratio. Asserts the
//!    PR's acceptance bar: **≥ 10×** end-to-end throughput at the top
//!    rung.
//! 2. **Fusion** — `potrf→potrs→potri` as three separate submits vs
//!    one [`SolveDag`]; the fused chain must be strictly faster (the
//!    intermediate gathers, re-scatters and re-factorizations vanish).
//! 3. **Reuse trace** — the fleet mix under
//!    [`Population::gp_vmc_mix_reuse`] (K hot matrices, 10% churn)
//!    replayed bitwise-identically through a cache-off and a cache-on
//!    service; the cached replay must finish in strictly less
//!    simulated time and report a non-zero hit count.
//!
//! `CACHE_BENCH_SMOKE=1` shrinks the rungs and repeat counts for
//! `make bench-cache` (CI test mode); every asserted invariant is
//! identical. Results are recorded in EXPERIMENTS.md.

use jaxmg::coordinator::{DistRoutine, SmallConfig, SolveDag, SolveService};
use jaxmg::linalg::Matrix;
use jaxmg::prelude::*;
use jaxmg::workload::{submit_spec, OpenLoop, Population};

const NDEV: usize = 4;
const TILE: usize = 16;
const SEED: u64 = 2026;

fn service(node: &SimNode, cached: bool) -> SolveService {
    let mut cfg = SmallConfig::with_tile(TILE);
    cfg.factor_cache = cached;
    SolveService::with_small_config(node.clone(), 1, cfg)
}

/// Submit `reps` identical `potrs` solves back-to-back and return the
/// simulated ns the batch occupied (measured from `from_ns`, so a
/// warmed service excludes its seeding factorization).
fn run_repeats(
    node: &SimNode,
    svc: &SolveService,
    a: &Matrix<f64>,
    b: &Matrix<f64>,
    reps: usize,
    from_ns: u64,
) -> (u64, usize) {
    let handles: Vec<_> = (0..reps)
        .map(|_| svc.submit_dist(DistRoutine::Potrs, a.clone(), Some(b.clone())).expect("submit"))
        .collect();
    let mut hits = 0usize;
    for h in handles {
        let (_, stats) = h.wait();
        if stats.cache_hit {
            hits += 1;
        }
    }
    (node.sim_time_ns() - from_ns, hits)
}

/// One ladder rung: cold vs warmed-cache throughput for order `n`.
/// Returns `(cold_ns, hot_ns)` for `reps` solves each.
fn rung(n: usize, reps: usize) -> (u64, u64) {
    let a = Matrix::<f64>::spd_random(n, SEED ^ n as u64);
    let b = Matrix::<f64>::random(n, 1, SEED + 7);

    let cold_node = SimNode::new_uniform(NDEV, 1 << 28);
    let cold_svc = service(&cold_node, false);
    let (cold_ns, cold_hits) = run_repeats(&cold_node, &cold_svc, &a, &b, reps, 0);
    assert_eq!(cold_hits, 0, "a cache-off service can never report hits");
    cold_svc.drain();

    let hot_node = SimNode::new_uniform(NDEV, 1 << 28);
    let hot_svc = service(&hot_node, true);
    // Warm: the first sight of A factors cold and seeds the cache.
    let (_, warm) = hot_svc
        .submit_dist(DistRoutine::Potrs, a.clone(), Some(b.clone()))
        .expect("warm")
        .wait();
    assert!(!warm.cache_hit, "first sight of A cannot hit");
    assert_eq!(hot_svc.cached_factors(), 1, "the warm solve must leave L resident");
    let warm_ns = hot_node.sim_time_ns();
    let (hot_ns, hot_hits) = run_repeats(&hot_node, &hot_svc, &a, &b, reps, warm_ns);
    assert_eq!(hot_hits, reps, "every repeat against the warm cache must hit");
    hot_svc.drain();

    (cold_ns, hot_ns)
}

fn main() {
    let smoke = std::env::var_os("CACHE_BENCH_SMOKE").is_some();

    // ---- 1. hit ladder -----------------------------------------------------
    let rungs: &[usize] = if smoke { &[96, 192] } else { &[128, 256, 512] };
    let reps = if smoke { 6 } else { 24 };
    println!(
        "== hit ladder: {reps}x repeated f64 potrs (nrhs 1) on {NDEV} devices, \
         cold service vs warmed factor cache ==\n"
    );
    println!(
        "{:>6} {:>14} {:>14} {:>16} {:>16} {:>8}",
        "n", "cold[ms]", "cached[ms]", "cold[req/s]", "cached[req/s]", "speedup"
    );
    let mut best_ratio = 0.0f64;
    for &n in rungs {
        let (cold_ns, hot_ns) = rung(n, reps);
        assert!(hot_ns > 0, "hit path must still consume simulated time");
        let ratio = cold_ns as f64 / hot_ns as f64;
        best_ratio = best_ratio.max(ratio);
        println!(
            "{:>6} {:>14.3} {:>14.3} {:>16.1} {:>16.1} {:>7.1}x",
            n,
            cold_ns as f64 * 1e-6,
            hot_ns as f64 * 1e-6,
            reps as f64 / (cold_ns as f64 * 1e-9),
            reps as f64 / (hot_ns as f64 * 1e-9),
            ratio
        );
        assert!(ratio > 1.0, "n={n}: the hit path must beat re-factorizing");
    }
    assert!(
        best_ratio >= 10.0,
        "resident-factor hits must deliver >=10x repeated-solve throughput \
         over cold factorization; best rung reached {best_ratio:.1}x"
    );

    // ---- 2. fusion: three submits vs one DAG -------------------------------
    let n = if smoke { 128 } else { 256 };
    let a = Matrix::<f64>::spd_random(n, SEED + 11);
    let b = Matrix::<f64>::random(n, 2, SEED + 13);

    let sep_node = SimNode::new_uniform(NDEV, 1 << 28);
    let sep_svc = service(&sep_node, false);
    let _ = sep_svc.submit_dist(DistRoutine::Potrf, a.clone(), None).expect("potrf").wait();
    let _ = sep_svc
        .submit_dist(DistRoutine::Potrs, a.clone(), Some(b.clone()))
        .expect("potrs")
        .wait();
    let _ = sep_svc.submit_dist(DistRoutine::Potri, a.clone(), None).expect("potri").wait();
    let sep_ns = sep_node.sim_time_ns();
    sep_svc.drain();

    let dag_node = SimNode::new_uniform(NDEV, 1 << 28);
    let dag_svc = service(&dag_node, false);
    let chain = SolveDag::new(a.clone()).factor().solve(b.clone()).inverse();
    let handles = dag_svc.submit_dag(chain).expect("dag");
    for h in handles {
        let (_, stats) = h.wait();
        assert_eq!(stats.fused_stages, 3, "each stage result must report the chain length");
    }
    let dag_ns = dag_node.sim_time_ns();
    let fused = dag_node.metrics().snapshot().dag_fused_stages;
    dag_svc.drain();

    println!(
        "\n== fusion: potrf -> potrs -> potri at n={n} ==\n\n\
         separate submits {:>10.3} ms | fused DAG {:>10.3} ms | {:.2}x \
         ({fused} stages fused)",
        sep_ns as f64 * 1e-6,
        dag_ns as f64 * 1e-6,
        sep_ns as f64 / dag_ns as f64
    );
    assert!(
        dag_ns < sep_ns,
        "the fused chain ({dag_ns} ns) must beat three separate submits ({sep_ns} ns)"
    );

    // ---- 3. reuse-correlated fleet trace -----------------------------------
    // Long enough that the 4-matrix hot pool must repeat (pigeonhole on
    // the 30%-weight VMC template alone), so the hit assertions below
    // are structural, not a property of one lucky trace seed.
    let count = if smoke { 48 } else { 160 };
    let trace = OpenLoop::new(
        ArrivalProcess::Poisson { rate_hz: 50.0 },
        Population::gp_vmc_mix_reuse(4, 0.10),
        SEED + 17,
    )
    .trace(count);

    let mut times = [0u64; 2];
    let mut hit_rate = 0.0;
    for (i, cached) in [false, true].into_iter().enumerate() {
        let node = SimNode::new_uniform(NDEV, 1 << 28);
        let svc = service(&node, cached);
        // Replay the identical arrivals back-to-back — no clock pacing.
        let pending: Vec<_> = trace
            .iter()
            .map(|arr| submit_spec(&svc, &arr.spec, node.sim_time_ns()).expect("trace submit"))
            .collect();
        svc.flush_small();
        for p in pending {
            p.wait().expect("trace request failed");
        }
        svc.drain();
        times[i] = node.sim_time_ns();
        let m = node.metrics().snapshot();
        if cached {
            assert!(m.cache_hits > 0, "a 4-hot / 10%-churn trace must produce repeat hits");
            hit_rate = m.cache_hit_rate();
        } else {
            assert_eq!(m.cache_hits + m.cache_misses, 0, "cache off means no probes");
        }
    }
    println!(
        "\n== reuse trace: {count} arrivals of gp_vmc_mix_reuse(hot=4, churn=0.10) ==\n\n\
         cache off {:>10.3} ms | cache on {:>10.3} ms | {:.2}x ; hit rate {:.0}%",
        times[0] as f64 * 1e-6,
        times[1] as f64 * 1e-6,
        times[0] as f64 / times[1] as f64,
        hit_rate * 100.0
    );
    assert!(
        times[1] < times[0],
        "the cached replay ({} ns) must finish before the cold one ({} ns)",
        times[1],
        times[0]
    );

    println!("\ncache bench OK");
}
