//! Fig. 3c: `syevd` float64 — JAXMg vs `jnp.linalg.eigh`.
//!
//! Measured small-N section + analytic paper-scale section. Key paper
//! observation asserted: tile size has **negligible** impact on syevd
//! (the reduction is bandwidth-bound and unblocked), and syevd's
//! workspace wall is the lowest of the three routines.

use jaxmg::coordinator::{ExecMode, JaxMg, Mesh};
use jaxmg::costmodel::Predictor;
use jaxmg::prelude::*;
use jaxmg::scalar::DType;
use std::time::Instant;

fn main() {
    println!("== Fig. 3c: syevd float64, 8 devices ==\n");
    println!("-- measured (simulator executes; diag(1..N): λᵢ = i+1 exactly) --");
    println!("{:>6} {:>5} {:>12} {:>12} {:>12}", "N", "T_A", "wall[ms]", "proj[ms]", "max|λ err|");
    for &n in &[64usize, 128, 192] {
        for &t in &[8usize, 16, 32] {
            if n % t != 0 {
                continue;
            }
            let node = SimNode::new_uniform(8, 1 << 30);
            let ctx = JaxMg::builder()
                .mesh(Mesh::new_1d(node, "x"))
                .tile_size(t)
                .exec_mode(ExecMode::Spmd)
                .build()
                .unwrap();
            let a = Matrix::<f64>::spd_diag(n);
            ctx.reset_accounting();
            let t0 = Instant::now();
            let (vals, _) = ctx.syevd(&a).unwrap();
            let wall = t0.elapsed().as_secs_f64() * 1e3;
            let err = (0..n).map(|i| (vals[i] - (i + 1) as f64).abs()).fold(0.0, f64::max);
            println!(
                "{n:>6} {t:>5} {wall:>12.2} {:>12.3} {err:>12.3e}",
                ctx.projected_time() * 1e3
            );
        }
    }

    println!("\n-- paper scale (analytic, 8×H200, float64) --");
    let p = Predictor::h200(8, DType::F64);
    let tiles = [64usize, 128, 256, 512];
    let vram = 143usize * 1000 * 1000 * 1000;
    let single_wall = p.single_capacity("syevd", vram);
    let dist_wall = p.dist_capacity("syevd", vram, 8, 512);
    print!("{:>9}", "N");
    for t in tiles {
        print!("  jaxmg T={t:<5}");
    }
    println!("  {:>12}", "single[s]");
    let mut n = 2048usize;
    while n <= 131072 {
        print!("{n:>9}");
        for t in tiles {
            if n > dist_wall {
                print!("  {:>12}", "OOM");
            } else {
                print!("  {:>12.3}", p.syevd(n, t, 8));
            }
        }
        if n > single_wall {
            println!("  {:>12}", "OOM");
        } else {
            println!("  {:>12.3}", p.single_syevd(n));
        }
        n *= 2;
    }
    println!("\ncapacity walls: single-GPU N≈{single_wall}, jaxmg N≈{dist_wall}");

    // Shape assertions.
    let flat = p.syevd(65536, 64, 8) / p.syevd(65536, 512, 8);
    assert!(
        (flat - 1.0).abs() < 0.05,
        "syevd must be nearly tile-size independent (got ratio {flat:.3})"
    );
    let dist_potrs = Predictor::h200(8, DType::F64).dist_capacity("potrs", vram, 8, 512);
    assert!(dist_wall < dist_potrs, "syevd workspace must cut reach below potrs");
    println!("shape checks: T_A flatness ✓  workspace wall ✓");
}
