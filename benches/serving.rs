//! Serving-front comparison: the SPMD `SolveService` vs the MPMD
//! one-process-per-GPU subsystem on identical workloads.
//!
//! Three sections, each printing measured (CPU) and projected
//! (cost-model) numbers:
//!
//! 1. **front parity** — the same distributed potrs stream through
//!    both fronts; asserts bitwise-identical results and that the MPMD
//!    projection carries exactly the modeled per-solve `cudaIpc`
//!    round-trip (`Predictor::mpmd_overhead`), nothing more.
//! 2. **failure drill** — a stream with a worker killed mid-workload;
//!    asserts zero lost requests and drained reservations.
//! 3. **cost model** — the `mpmd_overhead` ladder by device count next
//!    to a paper-scale solve, showing the overhead is control-plane
//!    noise at scale.
//!
//! `SERVE_BENCH_SMOKE=1` shrinks the workload for `make bench-serve`
//! (CI test mode); every asserted invariant is identical.

use jaxmg::batch::SmallRoutine;
use jaxmg::coordinator::{SmallConfig, SolveService};
use jaxmg::costmodel::{GpuCostModel, Predictor};
use jaxmg::linalg::{tol_for, FrobNorm, Matrix};
use jaxmg::prelude::*;
use jaxmg::scalar::DType;
use std::time::Instant;

fn main() {
    let smoke = std::env::var_os("SERVE_BENCH_SMOKE").is_some();
    let ndev = 4usize;
    let tile = if smoke { 8 } else { 32 };
    let n = if smoke { 48 } else { 192 };
    let solves = if smoke { 4 } else { 16 };

    // ---- 1. front parity ---------------------------------------------
    println!("== serving fronts: SPMD SolveService vs MPMD ({solves} × potrs n={n}, {ndev} devices, f64) ==\n");
    let systems: Vec<(Matrix<f64>, Matrix<f64>, Matrix<f64>)> = (0..solves)
        .map(|i| {
            let a = Matrix::<f64>::spd_random(n, i as u64);
            let xt = Matrix::<f64>::random(n, 1, 1000 + i as u64);
            let b = a.matmul(&xt);
            (a, xt, b)
        })
        .collect();

    // Serial submission (wait each solve out) keeps the projected
    // clocks deterministic: concurrent tenants interleave their sync
    // charges, which would blur the exact overhead comparison below.
    let spmd_node = SimNode::new_uniform(ndev, 1 << 30);
    let t0 = Instant::now();
    let spmd_results: Vec<Matrix<f64>> = {
        let mut cfg = SmallConfig::with_tile(tile);
        cfg.policy.small_dim = 0;
        let svc = SolveService::with_small_config(spmd_node.clone(), 1, cfg);
        let out = systems
            .iter()
            .map(|(a, _, b)| {
                svc.submit_small(SmallRoutine::Potrs, a.clone(), Some(b.clone()))
                    .unwrap()
                    .wait()
                    .0
            })
            .collect();
        svc.drain();
        out
    };
    let spmd_wall = t0.elapsed().as_secs_f64();

    let mpmd_node = SimNode::new_uniform(ndev, 1 << 30);
    let t0 = Instant::now();
    let (mpmd_results, mpmd_metrics): (Vec<Matrix<f64>>, _) = {
        let svc = MpmdService::with_config(mpmd_node.clone(), MpmdConfig::with_tile(tile));
        let out: Vec<Matrix<f64>> = systems
            .iter()
            .map(|(a, _, b)| svc.submit_potrs(a.clone(), b.clone()).unwrap().wait().0)
            .collect();
        svc.drain();
        (out, mpmd_node.metrics().snapshot())
    };
    let mpmd_wall = t0.elapsed().as_secs_f64();

    for (i, (s, m)) in spmd_results.iter().zip(&mpmd_results).enumerate() {
        assert_eq!(s.as_slice(), m.as_slice(), "solve {i}: MPMD diverges from SPMD");
    }
    let p = Predictor {
        model: GpuCostModel::h200(),
        topo: mpmd_node.topology().clone(),
        dtype: DType::F64,
    };
    let overhead = p.mpmd_overhead(ndev) * solves as f64;
    let gap = mpmd_node.sim_time() - spmd_node.sim_time();
    println!(
        "{:>8} {:>12} {:>14} {:>14} {:>12}",
        "front", "wall[ms]", "projected[ms]", "ipc opens", "requeues"
    );
    println!(
        "{:>8} {:>12.2} {:>14.4} {:>14} {:>12}",
        "SPMD",
        spmd_wall * 1e3,
        spmd_node.sim_time() * 1e3,
        "-",
        "-"
    );
    println!(
        "{:>8} {:>12.2} {:>14.4} {:>14} {:>12}",
        "MPMD",
        mpmd_wall * 1e3,
        mpmd_node.sim_time() * 1e3,
        mpmd_metrics.ipc_opens,
        mpmd_metrics.mpmd_requeues
    );
    println!(
        "\nprojection gap {:.1} µs vs modeled {solves} × mpmd_overhead = {:.1} µs",
        gap * 1e6,
        overhead * 1e6
    );
    assert!(gap > 0.0, "MPMD must pay a positive control-plane overhead");
    assert!(
        (gap - overhead).abs() <= overhead * 1e-6 + 1e-12,
        "charged overhead {gap} != modeled {overhead}"
    );
    assert_eq!(mpmd_metrics.ipc_exports, ((ndev - 1) * solves) as u64);
    assert_eq!(mpmd_metrics.ipc_open_balance(), 0, "caller leaked ipc mappings");
    println!(
        "mean frontend routing latency: {:.1} µs",
        mpmd_metrics.avg_routing_latency() * 1e6
    );

    // ---- 2. failure drill --------------------------------------------
    println!("\n== failure drill: worker 1 killed mid-stream ==\n");
    let node = SimNode::new_uniform(ndev, 1 << 30);
    let svc = MpmdService::with_config(node.clone(), MpmdConfig::with_tile(tile));
    let handles: Vec<_> = systems
        .iter()
        .map(|(a, _, b)| svc.submit_potrs(a.clone(), b.clone()).unwrap())
        .collect();
    svc.kill_worker(1).unwrap();
    let mut done = 0usize;
    for (h, (_, xt, _)) in handles.into_iter().zip(&systems) {
        let (x, _) = h.wait();
        assert!(x.rel_err(xt) < tol_for::<f64>(n) * 10.0, "request lost in the kill drill");
        done += 1;
    }
    svc.drain();
    let m = node.metrics().snapshot();
    println!(
        "{done}/{solves} completed on {:?} (re-queues: {}, peak mailbox: {})",
        svc.alive_workers(),
        m.mpmd_requeues,
        m.mpmd_peak_worker_queue
    );
    assert_eq!(done, solves);
    assert_eq!(svc.reserved(), vec![0; ndev], "kill drill leaked reservations");

    // ---- 3. the overhead ladder --------------------------------------
    println!("\n== Predictor::mpmd_overhead by device count (f32 potrs reference) ==\n");
    println!("{:>6} {:>16} {:>22}", "ndev", "overhead [µs]", "vs potrs n=131072 [%]");
    for nd in [2usize, 4, 8] {
        let pd = Predictor::h200(nd, DType::F32);
        let ov = pd.mpmd_overhead(nd);
        let solve = pd.potrs(131_072, 1024, nd, 1);
        println!("{nd:>6} {:>16.2} {:>21.5}%", ov * 1e6, ov / solve * 100.0);
    }
    println!("\nserving bench OK");
}
