//! The grid stack bench: 2D redistribution hops, grid-native potrf
//! (the §5 execution model), and the 1D-vs-2D analytic ladder.
//!
//! Four sections, each asserting the invariants it prints:
//!
//! 1. **2D redistribution** — the tile-cycle / re-tiling hops from
//!    `benches/redistribution.rs`'s grid section, kept as a conversion
//!    smoke matrix for the grid compute layouts the solvers now run on.
//! 2. **grid-native potrf** (simulated) — the same factor on the 1D
//!    layout and a 2×2 grid: bitwise-identical numerics, the row/column
//!    ring traffic split, and the strict lookahead-beats-barrier win on
//!    the grid schedule.
//! 3. **analytic ladder** — `Predictor::{potrf2d, potrs2d}` vs the 1D
//!    formulas at paper scale: where 2D starts winning, and what
//!    `Predictor::best_grid` selects per shape.
//! 4. **grid serving** — a `SolveService` pinned to a 2×2 grid serving
//!    requests bitwise-identically to the 1D service.
//!
//! `GRID_BENCH_SMOKE=1` shrinks the shapes for `make bench-grid` (CI
//! test mode); every asserted invariant is identical.

use jaxmg::coordinator::{DistRoutine, SmallConfig, SolveService};
use jaxmg::costmodel::{GpuCostModel, Predictor};
use jaxmg::layout::{BlockCyclic1D, BlockCyclic2D, ContiguousGrid2D, Redistributor};
use jaxmg::linalg::Matrix;
use jaxmg::prelude::*;
use jaxmg::scalar::DType;
use jaxmg::solver::{potrf_dist, Ctx};
use jaxmg::tile::{DistMatrix, LayoutKind};
use std::time::Instant;

fn main() {
    let smoke = std::env::var_os("GRID_BENCH_SMOKE").is_some();

    // ---- 1. 2D redistribution hops -----------------------------------
    println!("== 2D tile grid: conversion hops into the compute layouts ==\n");
    println!(
        "{:>22} {:>6} {:>6} {:>8} {:>8} {:>12} {:>9}",
        "conversion", "N", "tile", "cycles", "tiles", "path", "wall[ms]"
    );
    let n2 = if smoke { 256 } else { 1024 };
    let tile = if smoke { 32 } else { 64 };
    let node = SimNode::new_uniform(4, 1 << 30);
    let a = Matrix::<f32>::random(n2, n2, 99);
    let shard2d = LayoutKind::GridContig(ContiguousGrid2D::new(n2, n2, tile, tile, 2, 2).unwrap());
    let grid22 = LayoutKind::Grid(BlockCyclic2D::new(n2, n2, tile, tile, 2, 2).unwrap());
    let grid41 = LayoutKind::Grid(BlockCyclic2D::new(n2, n2, tile, tile, 4, 1).unwrap());
    let cyc1d = LayoutKind::BlockCyclic(BlockCyclic1D::new(n2, tile, 4).unwrap());
    let mut dm = DistMatrix::scatter(&node, &a, shard2d).unwrap();
    for (label, target, expect_in_place) in [
        ("2D shard → 2D cyclic", grid22, true),
        ("2×2 → 4×1 regrid", grid41, true),
        ("4×1 → 1D re-tiling", cyc1d, false),
        ("1D → 2×2 re-tiling", grid22, false),
    ] {
        let t0 = Instant::now();
        let plan = Redistributor::convert(&mut dm, target).unwrap();
        let wall = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "{label:>22} {n2:>6} {tile:>6} {:>8} {:>8} {:>12} {wall:>9.2}",
            plan.nontrivial_cycles,
            plan.tiles_moved,
            if plan.in_place { "in-place" } else { "out-of-place" },
        );
        assert_eq!(plan.in_place, expect_in_place, "{label}: wrong path");
        assert_eq!(dm.gather().unwrap(), a, "{label} corrupted content");
    }
    drop(dm);

    // ---- 2. grid-native potrf (simulated) -----------------------------
    println!("\n== grid-native potrf: 1D (1x4) vs 2x2, 4 devices, f64 ==\n");
    println!(
        "{:>6} {:>6} {:>8} {:>9} {:>14} {:>12} {:>12}",
        "N", "tile", "layout", "schedule", "makespan[µs]", "row[KiB]", "col[KiB]"
    );
    let (gn, gt) = if smoke { (32usize, 4usize) } else { (64, 8) };
    let model = GpuCostModel::h200();
    let am = Matrix::<f64>::spd_random(gn, 0xD15C0 + gn as u64);
    let mut factors: Vec<Matrix<f64>> = Vec::new();
    let mut makespans = [[0.0f64; 2]; 2]; // [layout][schedule]
    for (li, grid) in [false, true].into_iter().enumerate() {
        for (si, look) in [0usize, 2].into_iter().enumerate() {
            let node = SimNode::new_uniform(4, 1 << 28);
            let backend = SolverBackend::<f64>::Native;
            let ctx = Ctx::with_pipeline(&node, &model, &backend, PipelineConfig::lookahead(look));
            let lay = if grid {
                LayoutKind::Grid(BlockCyclic2D::new(gn, gn, gt, gt, 2, 2).unwrap())
            } else {
                LayoutKind::BlockCyclic(BlockCyclic1D::new(gn, gt, 4).unwrap())
            };
            let mut dm = DistMatrix::scatter(&node, &am, lay).unwrap();
            node.reset_accounting();
            potrf_dist(&ctx, &mut dm).unwrap();
            let m = node.metrics().snapshot();
            makespans[li][si] = node.sim_time();
            println!(
                "{gn:>6} {gt:>6} {:>8} {:>9} {:>14.3} {:>12.1} {:>12.1}",
                if grid { "2x2" } else { "1x4" },
                if look == 0 { "barrier" } else { "look(2)" },
                node.sim_time() * 1e6,
                m.grid_row_bytes as f64 / 1024.0,
                m.grid_col_bytes as f64 / 1024.0,
            );
            if grid {
                assert!(m.grid_row_bytes > 0 && m.grid_col_bytes > 0);
                assert_eq!(m.grid_solves, 1);
            } else {
                assert_eq!(m.grid_solves, 0);
            }
            factors.push(dm.gather().unwrap());
        }
    }
    for f in &factors[1..] {
        assert_eq!(
            factors[0].as_slice(),
            f.as_slice(),
            "layouts/schedules must agree bitwise on the factor"
        );
    }
    assert!(
        makespans[1][1] < makespans[1][0],
        "grid lookahead {} must strictly beat grid barrier {}",
        makespans[1][1],
        makespans[1][0]
    );

    // ---- 3. analytic ladder -------------------------------------------
    println!("\n== projected potrf/potrs makespans (f64, T_A=1024, 4 devices) ==\n");
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "N", "potrf 1D[s]", "potrf 2x2", "potrs 1D[s]", "potrs 2x2", "best_grid"
    );
    let p4 = Predictor::h200(4, DType::F64);
    let ladder: &[usize] =
        if smoke { &[4096, 16384, 65536] } else { &[4096, 16384, 65536, 131072] };
    for &n in ladder {
        let t = 1024;
        let pf1 = p4.potrf(n, t, 4);
        let pf2 = p4.potrf2d(n, t, 2, 2);
        let ps1 = p4.potrs(n, t, 4, 1);
        let ps2 = p4.potrs2d(n, t, 2, 2, 1);
        let bg = p4.best_grid("potrf", n, 0, t, 4);
        println!(
            "{n:>8} {pf1:>12.4} {pf2:>12.4} {ps1:>12.4} {ps2:>12.4} {:>7}x{}",
            bg.0, bg.1
        );
        if n >= 16384 {
            assert!(pf2 < pf1, "2x2 potrf must beat 1D at n={n}");
            assert!(ps2 < ps1, "2x2 potrs must beat 1D at n={n}");
            assert!(bg.0 > 1, "the selector must go 2D at n={n}");
        }
        // p = 1 degenerates bitwise to the 1D formulas.
        assert_eq!(p4.potrf2d(n, t, 1, 4), p4.potrf(n, t, 4));
        assert_eq!(p4.potrs2d(n, t, 1, 4, 1), p4.potrs(n, t, 4, 1));
    }
    println!("\n(small N keeps (1,ndev): ring latency dominates; the selector flips 2D");
    println!(" once the row-split panel trsm pays — the 2D-aware services inherit this)");

    // ---- 4. grid serving ----------------------------------------------
    println!("\n== 2D-aware serving: SolveService pinned to 2x2 vs 1D ==\n");
    let sn = if smoke { 24 } else { 48 };
    let stile = 8;
    let sa = Matrix::<f64>::spd_random(sn, 7);
    let sb = Matrix::<f64>::random(sn, 1, 8);
    let run = |grid: Option<(usize, usize)>| -> (Matrix<f64>, (usize, usize)) {
        let node = SimNode::new_uniform(4, 1 << 26);
        let mut cfg = SmallConfig::with_tile(stile);
        cfg.grid = grid;
        let svc = SolveService::with_small_config(node, 2, cfg);
        let (x, stats) =
            svc.submit_dist(DistRoutine::Potrs, sa.clone(), Some(sb.clone())).unwrap().wait();
        svc.drain();
        (x, stats.grid)
    };
    let (x1, g1) = run(None);
    let (x2, g2) = run(Some((2, 2)));
    println!("autotuned grid {g1:?}   pinned grid {g2:?}   bitwise-equal results: true");
    assert_eq!(g1, (1, 4), "small serving shapes stay 1D");
    assert_eq!(g2, (2, 2));
    assert_eq!(x1.as_slice(), x2.as_slice(), "grid serving changed numerics");

    println!("\ngrid bench OK");
}
