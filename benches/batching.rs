//! Batched small-solve throughput: the coalesced pod sweep against the
//! serial one-at-a-time distributed path.
//!
//! Three sections, each printing measured (CPU) and projected
//! (cost-model) numbers:
//!
//! 1. **sweep vs serial** — a B-solve small-matrix workload through the
//!    fused pod sweeps vs B back-to-back distributed solves; asserts
//!    the batched projected makespan is *strictly* smaller (the
//!    acceptance claim) and that both paths agree numerically.
//! 2. **service** — the same stream end-to-end through
//!    `SolveService::submit_small`, coalescing on vs forced
//!    distributed; reports bucket occupancy and coalesce waits.
//! 3. **cost model** — the `Predictor::batched_crossover` ladder: the
//!    per-size-class batched/serial makespans and the class where
//!    batching stops winning.
//!
//! `BATCH_BENCH_SMOKE=1` shrinks the workload for `make bench-batch`
//! (CI test mode); every asserted invariant is identical.

use jaxmg::batch::{potrf_batched, potrs_batched, PackedPod, SmallRoutine};
use jaxmg::coordinator::SmallConfig;
use jaxmg::costmodel::{GpuCostModel, Predictor};
use jaxmg::layout::BlockCyclic1D;
use jaxmg::linalg::Matrix;
use jaxmg::prelude::*;
use jaxmg::scalar::DType;
use jaxmg::solver::{potrf_dist, potrs_dist, Ctx};
use jaxmg::tile::{DistMatrix, Layout1D};
use std::time::Instant;

fn main() {
    let smoke = std::env::var_os("BATCH_BENCH_SMOKE").is_some();
    let b = if smoke { 64 } else { 256 };
    let ndev = 8usize;
    let model = GpuCostModel::h200();
    let backend = SolverBackend::<f64>::Native;

    println!("== batched pod sweep vs serial distributed ({b} solves, 8 devices, f64) ==\n");
    println!(
        "{:>4} {:>9} {:>12} {:>12} {:>8} {:>10} {:>10}",
        "n", "wall[ms]", "batch[ms]", "serial[ms]", "speedup", "launches", "peerB"
    );
    for &n in &[16usize, 32, 64] {
        let systems: Vec<Matrix<f64>> =
            (0..b).map(|i| Matrix::spd_random(n, i as u64)).collect();
        let rhss: Vec<Matrix<f64>> =
            (0..b).map(|i| Matrix::random(n, 1, 4000 + i as u64)).collect();

        // Batched: pack → fused potrf/potrs sweeps → gather.
        let node_b = SimNode::new_uniform(ndev, 1 << 28);
        let ctx_b = Ctx::new(&node_b, &model, &backend);
        let t0 = Instant::now();
        let mut pod = PackedPod::pack(&node_b, &systems).unwrap();
        let mut pod_rhs = PackedPod::pack(&node_b, &rhss).unwrap();
        potrf_batched(&ctx_b, &mut pod).unwrap();
        potrs_batched(&ctx_b, &pod, &mut pod_rhs).unwrap();
        let batched = pod_rhs.gather().unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let t_batched = node_b.sim_time();
        let mb = node_b.metrics().snapshot();

        // Serial: B full distributed solves back to back.
        let node_s = SimNode::new_uniform(ndev, 1 << 28);
        let ctx_s = Ctx::new(&node_s, &model, &backend);
        let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, (n / 2).max(1), ndev).unwrap());
        let mut serial = Vec::with_capacity(b);
        for i in 0..b {
            let mut dm = DistMatrix::scatter(&node_s, &systems[i], lay).unwrap();
            potrf_dist(&ctx_s, &mut dm).unwrap();
            serial.push(potrs_dist(&ctx_s, &dm, &rhss[i]).unwrap());
            dm.free().unwrap();
        }
        let t_serial = node_s.sim_time();

        println!(
            "{n:>4} {:>9.2} {:>12.4} {:>12.4} {:>7.0}x {:>10} {:>10}",
            wall * 1e3,
            t_batched * 1e3,
            t_serial * 1e3,
            t_serial / t_batched,
            mb.kernel_launches,
            mb.peer_bytes,
        );
        assert!(
            t_batched < t_serial,
            "batched {t_batched} !< serial {t_serial} at n={n}"
        );
        assert_eq!(mb.peer_bytes, 0, "pod sweeps must move no peer bytes");
        for i in 0..b {
            let diff = batched[i].sub(&serial[i]).norm_fro() / serial[i].norm_fro().max(1e-300);
            assert!(diff < 1e-9, "paths disagree at n={n}, solve {i}: {diff}");
        }
    }

    // ---- end-to-end through the service ------------------------------
    println!("\n== SolveService::submit_small: coalescing on vs forced distributed ==\n");
    println!(
        "{:>4} {:>12} {:>12} {:>8} {:>9} {:>12}",
        "n", "batch[ms]", "serial[ms]", "speedup", "buckets", "occupancy"
    );
    for &n in &[12usize, 24] {
        let systems: Vec<Matrix<f64>> =
            (0..b).map(|i| Matrix::spd_random(n, 77 + i as u64)).collect();
        let rhss: Vec<Matrix<f64>> =
            (0..b).map(|i| Matrix::random(n, 1, 7000 + i as u64)).collect();
        let run = |small_dim: usize| {
            let node = SimNode::new_uniform(4, 1 << 28);
            let mut cfg = SmallConfig::with_tile(16);
            cfg.policy.max_batch = 32;
            cfg.policy.small_dim = small_dim;
            let svc = SolveService::with_small_config(node.clone(), 2, cfg);
            let handles: Vec<_> = systems
                .iter()
                .zip(&rhss)
                .map(|(a, rhs)| {
                    svc.submit_small(SmallRoutine::Potrs, a.clone(), Some(rhs.clone())).unwrap()
                })
                .collect();
            svc.flush_small();
            for h in handles {
                let _ = h.wait();
            }
            svc.drain();
            (node.sim_time(), node.metrics().snapshot())
        };
        let (t_on, m_on) = run(4 * 16);
        let (t_off, m_off) = run(0);
        println!(
            "{n:>4} {:>12.4} {:>12.4} {:>7.1}x {:>9} {:>12.1}",
            t_on * 1e3,
            t_off * 1e3,
            t_off / t_on,
            m_on.batch_buckets,
            m_on.avg_batch_occupancy(),
        );
        assert!(t_on < t_off, "service batched {t_on} !< distributed {t_off} at n={n}");
        assert_eq!(m_on.batch_solves, b as u64);
        assert_eq!(m_off.batch_solves, 0);
    }

    // ---- the cost-model ladder ---------------------------------------
    println!("\n== Predictor: batched vs serial by size-class (T_A=256, 8 dev, 32-way) ==\n");
    println!("{:>8} {:>14} {:>14} {:>8}", "class", "batched[ms]", "serial[ms]", "wins");
    let p = Predictor::h200(8, DType::F64);
    let mut n = 16usize;
    while n <= 65536 {
        let pod = p.pod_sweep("potrs", n, 1, 8, 32);
        let serial = p.small_serial("potrs", n, 1, 256, 8, 32);
        println!(
            "{n:>8} {:>14.4} {:>14.4} {:>8}",
            pod * 1e3,
            serial * 1e3,
            if pod < serial { "yes" } else { "no" }
        );
        n *= 4;
    }
    let crossover = p.batched_crossover("potrs", 1, 256, 8, 32);
    println!("\ncrossover class (batching stops winning): {crossover}");
    assert_eq!(crossover, 32768);
    println!("\nbatching bench OK");
}
