//! Fleet traffic: the GP/VMC request mix through the SPMD front under
//! FIFO vs EDF/SJF scheduling.
//!
//! One deterministic bursty open-loop trace (same seed, bitwise the
//! same arrivals and inputs) is replayed against two services that
//! differ only in [`SchedPolicy`]. The ladder prints per-class p50/p99
//! end-to-end latency (cost-model ns), deadline misses, and panel
//! preemptions, and asserts the PR's acceptance criteria:
//!
//! * EDF/SJF strictly beats FIFO on interactive-class p99 — the burst
//!   pileups that FIFO serves in arrival order jump the queue under
//!   EDF, and batch-class factorizations yield at panel boundaries;
//! * no batch-class starvation: every batch request in the trace
//!   completes under EDF/SJF (the anti-starvation barrier);
//! * zero requests lost under either policy.
//!
//! A short closed-loop probe follows as the self-limiting counterpart.
//! Results are recorded in EXPERIMENTS.md. `TRAFFIC_BENCH_SMOKE=1`
//! shrinks the trace for `make bench-traffic` (CI test mode); every
//! asserted invariant is identical.

use jaxmg::coordinator::{SchedConfig, SchedPolicy, SloClass, SmallConfig, SolveService};
use jaxmg::metrics::MetricsSnapshot;
use jaxmg::prelude::*;
use jaxmg::workload::{ClosedLoop, OpenLoop, Population};

const NDEV: usize = 4;
const TILE: usize = 16;
const SEED: u64 = 2026;

fn traffic() -> OpenLoop {
    // Bursts at 20 kHz over a 20 Hz background: arrival clusters pile
    // up far faster than the fleet drains them, so the queue is deep
    // and scheduling order decides who eats the backlog.
    OpenLoop::new(
        ArrivalProcess::Bursty { idle_hz: 20.0, burst_hz: 20_000.0, burst_prob: 0.7 },
        Population::gp_vmc_mix(),
        SEED,
    )
}

fn run_open_loop(policy: SchedPolicy, count: usize) -> (MetricsSnapshot, usize) {
    let node = SimNode::new_uniform(NDEV, 1 << 28);
    let sched = SchedConfig { policy, ..SchedConfig::default() };
    let svc = SolveService::with_config(node.clone(), 1, SmallConfig::with_tile(TILE), sched);
    let pending = traffic().drive(&node, &svc, count).expect("trace submission failed");
    svc.flush_small();
    let mut failures = 0usize;
    for p in pending {
        if p.wait().is_err() {
            failures += 1;
        }
    }
    svc.drain();
    (node.metrics().snapshot(), failures)
}

fn main() {
    let smoke = std::env::var_os("TRAFFIC_BENCH_SMOKE").is_some();
    let count = if smoke { 30 } else { 150 };

    let trace = traffic().trace(count);
    let expected_batch = trace.iter().filter(|a| a.spec.class == SloClass::Batch).count() as u64;
    let n_interactive = trace.iter().filter(|a| a.spec.class == SloClass::Interactive).count();
    println!(
        "== open loop: {count} bursty arrivals of the GP/VMC mix ({n_interactive} interactive, \
         {expected_batch} batch) through 1 worker on {NDEV} devices ==\n"
    );

    let (fifo, fifo_failed) = run_open_loop(SchedPolicy::Fifo, count);
    let (edf, edf_failed) = run_open_loop(SchedPolicy::EdfSjf, count);

    println!(
        "{:>12} {:>14} {:>14} {:>14} {:>14} {:>10} {:>12}",
        "class",
        "fifo p50[ms]",
        "fifo p99[ms]",
        "edf p50[ms]",
        "edf p99[ms]",
        "misses",
        "misses(edf)"
    );
    for class in SloClass::ALL {
        let i = class.index();
        println!(
            "{:>12} {:>14.3} {:>14.3} {:>14.3} {:>14.3} {:>10} {:>12}",
            class.name(),
            fifo.class_p50_ns[i] as f64 * 1e-6,
            fifo.class_p99_ns[i] as f64 * 1e-6,
            edf.class_p50_ns[i] as f64 * 1e-6,
            edf.class_p99_ns[i] as f64 * 1e-6,
            fifo.class_deadline_misses[i],
            edf.class_deadline_misses[i]
        );
    }
    println!(
        "\npanel preemptions: fifo {} | edf {} ; completions per class: fifo {:?} | edf {:?}",
        fifo.service_preemptions, edf.service_preemptions, fifo.class_completed, edf.class_completed
    );

    assert_eq!(fifo_failed + edf_failed, 0, "open-loop traffic lost requests");
    let i = SloClass::Interactive.index();
    assert!(
        edf.class_p99_ns[i] < fifo.class_p99_ns[i],
        "EDF/SJF interactive p99 {} ns must strictly beat FIFO {} ns",
        edf.class_p99_ns[i],
        fifo.class_p99_ns[i]
    );
    let b = SloClass::Batch.index();
    assert_eq!(
        edf.class_completed[b], expected_batch,
        "batch-class work starved under EDF/SJF"
    );
    assert_eq!(
        fifo.class_completed[i], edf.class_completed[i],
        "both policies must complete the same interactive set"
    );

    // ---- closed loop: the self-limiting probe -------------------------
    let total = if smoke { 10 } else { 40 };
    println!("\n== closed loop: window of 4, {total} requests, think 1 µs ==\n");
    let node = SimNode::new_uniform(NDEV, 1 << 28);
    let svc = SolveService::with_config(
        node.clone(),
        2,
        SmallConfig::with_tile(TILE),
        SchedConfig { policy: SchedPolicy::EdfSjf, ..SchedConfig::default() },
    );
    let lp = ClosedLoop {
        population: Population::gp_vmc_mix(),
        concurrency: 4,
        think_ns: 1_000,
        seed: SEED + 1,
    };
    let results = lp.run(&node, &svc, total).expect("closed-loop submission failed");
    svc.drain();
    let mut sum_ns = 0u64;
    for r in &results {
        let stats = r.as_ref().expect("closed-loop request failed");
        sum_ns += stats.queue_wait_ns + stats.exec_ns;
    }
    println!(
        "{} requests in {:.3} ms simulated; mean end-to-end latency {:.3} ms",
        results.len(),
        node.sim_time() * 1e3,
        sum_ns as f64 / results.len() as f64 * 1e-6
    );
    assert_eq!(results.len(), total);

    println!("\ntraffic bench OK");
}
