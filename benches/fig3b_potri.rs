//! Fig. 3b: `potri` complex128 — JAXMg vs `jnp.linalg.inv`.
//!
//! Measured small-N section (the simulator executes the distributed
//! inverse, complex128) + analytic paper-scale section. Key paper
//! observations asserted: potri shows a **strong** T_A dependence
//! (Fig. 3 caption) and its workspace wall sits below potrs'.

use jaxmg::coordinator::{ExecMode, JaxMg, Mesh};
use jaxmg::costmodel::Predictor;
use jaxmg::linalg::FrobNorm;
use jaxmg::prelude::*;
use jaxmg::scalar::DType;
use std::time::Instant;

fn main() {
    println!("== Fig. 3b: potri complex128, 8 devices ==\n");
    println!("-- measured (simulator executes; diag(1..N)) --");
    println!("{:>6} {:>5} {:>12} {:>12} {:>12}", "N", "T_A", "wall[ms]", "proj[ms]", "resid");
    for &n in &[64usize, 128, 192] {
        for &t in &[8usize, 16, 32] {
            if n % t != 0 {
                continue;
            }
            let node = SimNode::new_uniform(8, 1 << 30);
            let ctx = JaxMg::builder()
                .mesh(Mesh::new_1d(node, "x"))
                .tile_size(t)
                .exec_mode(ExecMode::Spmd)
                .build()
                .unwrap();
            let a = Matrix::<c64>::spd_diag(n);
            ctx.reset_accounting();
            let t0 = Instant::now();
            let inv = ctx.potri(&a).unwrap();
            let wall = t0.elapsed().as_secs_f64() * 1e3;
            let resid = a.matmul(&inv).rel_err(&Matrix::eye(n));
            println!(
                "{n:>6} {t:>5} {wall:>12.2} {:>12.3} {resid:>12.3e}",
                ctx.projected_time() * 1e3
            );
        }
    }

    println!("\n-- paper scale (analytic, 8×H200, complex128) --");
    let p = Predictor::h200(8, DType::C128);
    let tiles = [64usize, 128, 256, 512];
    let vram = 143usize * 1000 * 1000 * 1000;
    let single_wall = p.single_capacity("potri", vram);
    let dist_wall = p.dist_capacity("potri", vram, 8, 512);
    print!("{:>9}", "N");
    for t in tiles {
        print!("  jaxmg T={t:<5}");
    }
    println!("  {:>12}", "single[s]");
    let mut n = 2048usize;
    while n <= 131072 {
        print!("{n:>9}");
        for t in tiles {
            if n > dist_wall {
                print!("  {:>12}", "OOM");
            } else {
                print!("  {:>12.3}", p.potri(n, t, 8));
            }
        }
        if n > single_wall {
            println!("  {:>12}", "OOM");
        } else {
            println!("  {:>12.3}", p.single_potri(n));
        }
        n *= 2;
    }
    println!("\ncapacity walls: single-GPU N≈{single_wall}, jaxmg N≈{dist_wall}");

    // Shape assertions.
    let strong_t = p.potri(65536, 64, 8) / p.potri(65536, 512, 8);
    assert!(strong_t > 1.5, "potri must depend strongly on T_A (got ratio {strong_t:.2})");
    let p_potrs = Predictor::h200(8, DType::C128);
    assert!(
        p_potrs.dist_capacity("potri", vram, 8, 512) < p_potrs.dist_capacity("potrs", vram, 8, 512),
        "potri workspace must cut its reach below potrs"
    );
    assert!(p.potri(65536, 512, 8) < p.single_potri(65536), "JAXMg wins at large N");
    println!("shape checks: strong T_A dependence ✓  workspace wall ✓  large-N win ✓");
}
