//! Quickstart: the paper's §2 usage example, end to end.
//!
//! ```text
//! mesh = jax.make_mesh((jax.device_count(),), ("x",))
//! out  = potrs(A, b, T_A=T_A, mesh=mesh, in_specs=(P("x", None), P(None, None)))
//! ```
//!
//! Run: `cargo run --release --example quickstart`

use jaxmg::prelude::*;

fn main() -> Result<()> {
    // An 8-GPU node (simulated; see DESIGN.md §Hardware substitution).
    let node = SimNode::new_uniform(8, 1 << 30);
    let mesh = Mesh::new_1d(node, "x");

    // T_A is the paper's tile-size knob: memory vs performance.
    let ctx = JaxMg::builder().mesh(mesh).tile_size(64).build()?;

    // The paper's benchmark problem: A = diag(1..N), b = ones.
    let n = 1024;
    let a = Matrix::<f64>::spd_diag(n);
    let b = Matrix::<f64>::ones(n, 1);

    // potrs with the paper's in_specs: A sharded P("x", None), b replicated.
    let x = ctx.potrs_with_specs(
        &a,
        &b,
        PartitionSpec::sharded("x"),
        PartitionSpec::replicated(),
    )?;

    // diag(1..N)·x = 1  ⇒  x_i = 1/(i+1).
    println!("x[0]   = {:.6}  (expect 1.000000)", x[(0, 0)]);
    println!("x[9]   = {:.6}  (expect 0.100000)", x[(9, 0)]);
    println!("x[{}] = {:.6}  (expect {:.6})", n - 1, x[(n - 1, 0)], 1.0 / n as f64);

    let m = ctx.metrics();
    println!(
        "\nsolved n={n} over {} devices: {} tile kernels, {:.1} MiB peer traffic, \
         projected H200 time {:.3} ms",
        ctx.mesh().num_devices(),
        m.kernel_launches,
        m.peer_bytes as f64 / (1 << 20) as f64,
        ctx.projected_time() * 1e3
    );
    Ok(())
}
