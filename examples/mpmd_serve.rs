//! MPMD serving demo: one simulated process per GPU, IPC-published
//! shards, a rank-0 frontend — and a mid-workload worker kill that
//! loses nothing.
//!
//! Run with `cargo run --release --example mpmd_serve`. The numbers at
//! the end (SPMD vs MPMD projection, the `Predictor::mpmd_overhead`
//! ladder, IPC counters) are recorded in EXPERIMENTS.md.

use jaxmg::batch::SmallRoutine;
use jaxmg::coordinator::{SmallConfig, SolveService};
use jaxmg::costmodel::Predictor;
use jaxmg::linalg::{tol_for, FrobNorm, Matrix};
use jaxmg::prelude::*;
use jaxmg::scalar::DType;

const NDEV: usize = 4;
const TILE: usize = 32;
const N: usize = 256;
const SMALLS: usize = 64;

fn main() {
    println!("== MPMD serving: {NDEV} worker processes, rank-0 frontend (f64) ==\n");

    // ---- the same workload through both fronts -----------------------
    let a = Matrix::<f64>::spd_random(N, 1);
    let xt = Matrix::<f64>::random(N, 1, 2);
    let b = a.matmul(&xt);

    let spmd_node = SimNode::new_uniform(NDEV, 1 << 30);
    let spmd_x = {
        let mut cfg = SmallConfig::with_tile(TILE);
        cfg.policy.small_dim = 0; // force the distributed route
        let svc = SolveService::with_small_config(spmd_node.clone(), 2, cfg);
        let (x, _) = svc
            .submit_small(SmallRoutine::Potrs, a.clone(), Some(b.clone()))
            .unwrap()
            .wait();
        svc.drain();
        x
    };

    let mpmd_node = SimNode::new_uniform(NDEV, 1 << 30);
    let svc = MpmdService::with_config(mpmd_node.clone(), MpmdConfig::with_tile(TILE));
    // With JAXMG_TRACE=<dir> the whole demo — including the kill drill
    // below — records request spans and scheduler decisions, exported
    // at the end as one downloadable trace artifact. The tracer is
    // passive: every number this demo prints (and the SPMD-vs-MPMD
    // bitwise assert) is identical with tracing on or off.
    let trace_dir = std::env::var("JAXMG_TRACE").ok();
    if trace_dir.is_some() {
        svc.tracer().enable();
    }
    let (mpmd_x, stats) = svc.submit_potrs(a.clone(), b.clone()).unwrap().wait();
    assert_eq!(
        spmd_x.as_slice(),
        mpmd_x.as_slice(),
        "MPMD must be bitwise identical to SPMD"
    );
    println!(
        "potrs n={N}: MPMD == SPMD bitwise; queued {:.2} ms, ran {:.2} ms",
        stats.queue_wait_secs() * 1e3,
        stats.exec_secs() * 1e3
    );
    let p = Predictor {
        model: jaxmg::costmodel::GpuCostModel::h200(),
        topo: mpmd_node.topology().clone(),
        dtype: DType::F64,
    };
    println!(
        "projected makespan: SPMD {:.3} ms | MPMD {:.3} ms | gap {:.1} µs (model: {:.1} µs)",
        spmd_node.sim_time() * 1e3,
        mpmd_node.sim_time() * 1e3,
        (mpmd_node.sim_time() - spmd_node.sim_time()) * 1e6,
        p.mpmd_overhead(NDEV) * 1e6
    );

    // ---- mixed traffic + a worker kill mid-workload ------------------
    println!("\n== kill test: {SMALLS} tiny solves + 4 distributed solves, worker 2 dies ==");
    let handles: Vec<_> = (0..4)
        .map(|_| svc.submit_potrs(a.clone(), b.clone()).unwrap())
        .collect();
    let small_handles: Vec<_> = (0..SMALLS)
        .map(|i| {
            let n = 12 + (i % 3) * 9;
            let sa = Matrix::<f64>::spd_random(n, 100 + i as u64);
            let sb = Matrix::<f64>::random(n, 1, 200 + i as u64);
            svc.submit_small(SmallRoutine::Potrs, sa, Some(sb)).unwrap()
        })
        .collect();
    svc.kill_worker(2).unwrap();
    println!("alive workers after kill: {:?}", svc.alive_workers());
    for h in handles {
        let (x, _) = h.wait();
        assert!(x.rel_err(&xt) < tol_for::<f64>(N) * 10.0, "distributed solve lost");
    }
    let mut coalesced = 0usize;
    for h in small_handles {
        let (x, s) = h.wait();
        assert!(x.rows() >= 12);
        if s.batch_size > 1 {
            coalesced += 1;
        }
    }
    svc.drain();
    let m = mpmd_node.metrics().snapshot();
    println!(
        "all {} requests completed; {coalesced}/{SMALLS} tiny solves coalesced",
        4 + SMALLS + 1
    );
    println!(
        "re-queues after the kill: {} | routed: {} | mean routing latency {:.1} µs",
        m.mpmd_requeues,
        m.mpmd_routed,
        m.avg_routing_latency() * 1e6
    );
    println!(
        "ipc: {} exports, {} opens, {} closes (balance {}), {} revokes",
        m.ipc_exports,
        m.ipc_opens,
        m.ipc_closes,
        m.ipc_open_balance(),
        m.ipc_revokes
    );
    println!("peak worker mailbox depth: {}", m.mpmd_peak_worker_queue);
    assert_eq!(m.ipc_open_balance(), 0, "rank 0 leaked ipc mappings");
    assert_eq!(svc.reserved(), vec![0; NDEV], "reservations must drain to zero");

    // ---- trace artifact: the kill drill as one downloadable trace ----
    if let Some(dir) = &trace_dir {
        use jaxmg::obs::{chrome_trace_json, decisions_jsonl, validate_chrome_json};
        let tracer = svc.tracer();
        let spans = tracer.spans();
        let json = chrome_trace_json(&spans);
        let events = validate_chrome_json(&json).expect("kill-drill trace must validate");
        let decisions = tracer.decisions();
        let jsonl = decisions_jsonl(&decisions);
        std::fs::create_dir_all(dir).expect("create trace output dir");
        let dir = std::path::Path::new(dir);
        std::fs::write(dir.join("mpmd_kill_drill.json"), &json).expect("write chrome trace");
        std::fs::write(dir.join("mpmd_kill_drill_decisions.jsonl"), &jsonl)
            .expect("write decision log");
        assert!(
            decisions.iter().any(|d| d.kind == "kill"),
            "the kill drill must log its kill decision"
        );
        let requeues = decisions.iter().filter(|d| d.kind == "requeue").count();
        println!(
            "trace artifact: {} span events, {} decisions ({} requeue) -> {}",
            events,
            decisions.len(),
            requeues,
            dir.display()
        );
    }

    // ---- the overhead ladder -----------------------------------------
    println!("\n== Predictor::mpmd_overhead (per distributed solve) ==\n");
    println!("{:>6} {:>14}", "ndev", "overhead [µs]");
    for ndev in [1usize, 2, 4, 8] {
        let pd = Predictor::h200(ndev, DType::F64);
        println!("{ndev:>6} {:>14.2}", pd.mpmd_overhead(ndev) * 1e6);
    }
    println!("\nmpmd_serve OK");
}
