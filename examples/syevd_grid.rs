//! 1D vs 2D block-cyclic `syevd` on the simulated node — the §5
//! future-work demo.
//!
//! Part 1 runs the real simulator at small N on the same 4 devices in
//! both layouts (1D `1×4` columns vs a `2×2` grid) and prints the
//! simulated makespans and communication volumes. At these tiny shapes
//! link latency dominates, so the layouts are close; the structural
//! difference shows in the peer-traffic split.
//!
//! Part 2 replays the schedules analytically at paper scale
//! (`Predictor::syevd` vs `Predictor::syevd2d`), where the 2×2 grid's
//! row-parallel reflector collectives strictly beat the row-bound 1D
//! layout — the reason the paper names the 2D distribution as the
//! eigensolver's unlock.
//!
//! Run with `cargo run --release --example syevd_grid`.

use jaxmg::costmodel::{GpuCostModel, Predictor};
use jaxmg::layout::{BlockCyclic1D, BlockCyclic2D};
use jaxmg::linalg::Matrix;
use jaxmg::prelude::*;
use jaxmg::scalar::DType;
use jaxmg::solver::{syevd_dist, Ctx};
use jaxmg::tile::{DistMatrix, LayoutKind};

fn main() {
    // ---- Part 1: the real simulator, small N, 4 devices ------------
    println!("== simulated syevd: 1D (1x4) vs 2D (2x2), 4 devices ==\n");
    println!("{:>6} {:>6} {:>8} {:>14} {:>14}", "N", "tile", "layout", "makespan[ms]", "peer[KiB]");
    let model = GpuCostModel::h200();
    for &n in &[16usize, 32, 48] {
        let tile = 4;
        let a = Matrix::<f64>::hermitian_random(n, 0x5EED + n as u64);
        for grid in [false, true] {
            let node = SimNode::new_uniform(4, 1 << 28);
            let backend = SolverBackend::<f64>::Native;
            let ctx = Ctx::pipelined(&node, &model, &backend);
            let lay = if grid {
                LayoutKind::Grid(BlockCyclic2D::new(n, n, tile, tile, 2, 2).unwrap())
            } else {
                LayoutKind::BlockCyclic(BlockCyclic1D::new(n, tile, 4).unwrap())
            };
            let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
            node.reset_accounting();
            let vals = syevd_dist(&ctx, &mut dm).unwrap();
            assert!(vals.windows(2).all(|w| w[0] <= w[1]), "eigenvalues must ascend");
            let m = node.metrics().snapshot();
            println!(
                "{n:>6} {tile:>6} {:>8} {:>14.3} {:>14.1}",
                if grid { "2x2" } else { "1x4" },
                node.sim_time() * 1e3,
                m.peer_bytes as f64 / 1024.0
            );
        }
    }

    // ---- Part 2: analytic replay at paper scale --------------------
    println!("\n== projected syevd makespan (f64): row-bound 1D vs 2D grid ==\n");
    println!(
        "{:>8} {:>6} {:>12} {:>12} {:>12} {:>8}",
        "N", "T_A", "1D 1x4 [s]", "2x2 [s]", "saved [ms]", "win"
    );
    let p4 = Predictor::h200(4, DType::F64);
    for &n in &[16384usize, 32768, 65536, 131072] {
        let t = 256;
        let one_d = p4.syevd(n, t, 4);
        let grid = p4.syevd2d(n, t, 2, 2);
        println!(
            "{n:>8} {t:>6} {one_d:>12.4} {grid:>12.4} {:>12.1} {:>8}",
            (one_d - grid) * 1e3,
            if grid < one_d { "2x2" } else { "1D" }
        );
        assert!(
            grid < one_d,
            "2x2 grid must strictly beat the 1D layout at paper scale (n={n})"
        );
    }
    println!("\n-- 8 devices: 1x8 vs 2x4 vs 4x2 --");
    let p8 = Predictor::h200(8, DType::F64);
    for &n in &[32768usize, 131072] {
        let t = 256;
        println!(
            "N={n:>7}  1x8 {:>9.4} s   2x4 {:>9.4} s   4x2 {:>9.4} s",
            p8.syevd(n, t, 8),
            p8.syevd2d(n, t, 2, 4),
            p8.syevd2d(n, t, 4, 2)
        );
    }
    println!("\n(1D: every reflector collective carries n words through one owner; 2D: P");
    println!(" parallel row groups carry n/P-long segments — §5's un-row-binding of syevd)");
}
