//! Domain example: stochastic reconfiguration (natural gradient) for
//! variational Monte Carlo — the NetKet-style workload the paper's
//! §1 cites as a driver for multi-GPU linear solves.
//!
//! Each optimization step solves the SR linear system
//!
//!     (S + λI) δ = g,     S = ⟨O†O⟩ − ⟨O†⟩⟨O⟩
//!
//! where `S` is a dense Hermitian PSD quantum geometric tensor over the
//! variational parameters. The factor-once/solve-many handle maps onto
//! `JaxMg::factorize`, and the solve runs distributed while the rest of
//! the toy VMC loop stays ordinary Rust — the composability story.
//!
//! Run: `cargo run --release --example vmc_sr`

use jaxmg::prelude::*;
use jaxmg::rng::Rng;

/// Toy model: mean-field wavefunction ψ_θ(σ) = Π tanh-parameterized
/// single-site amplitudes over `n_sites` spins; `n_params = n_sites`.
struct ToyVmc {
    theta: Vec<f64>,
    rng: Rng,
}

impl ToyVmc {
    fn new(n: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let theta = (0..n).map(|_| 0.2 * rng.next_signed()).collect();
        ToyVmc { theta, rng }
    }

    /// Draw `m` spin configurations and their log-derivative rows
    /// O_k(σ) = ∂ log ψ / ∂θ_k, plus local energies for a toy
    /// ferromagnetic Ising energy.
    fn sample(&mut self, m: usize) -> (Matrix<f64>, Vec<f64>) {
        let n = self.theta.len();
        let mut o = Matrix::<f64>::zeros(m, n);
        let mut e_loc = vec![0.0; m];
        for s in 0..m {
            let mut energy = 0.0;
            let mut prev = 1.0f64;
            for k in 0..n {
                let p = 0.5 * (1.0 + self.theta[k].tanh());
                let spin = if self.rng.next_f64() < p { 1.0 } else { -1.0 };
                // O_k = ∂ log ψ: for this parameterization, spin·sech²-ish.
                let th = self.theta[k].tanh();
                o[(s, k)] = spin * (1.0 - th * th) / (1.0 + spin * th).max(1e-9);
                if k > 0 {
                    energy -= prev * spin;
                }
                prev = spin;
            }
            e_loc[s] = energy;
        }
        (o, e_loc)
    }
}

fn main() -> Result<()> {
    let n_params = 96;
    let n_samples = 512;
    let lambda = 1e-3;
    let lr = 0.05;
    let steps = 10;

    let node = SimNode::new_uniform(4, 1 << 30);
    let ctx = JaxMg::builder().mesh(Mesh::new_1d(node, "x")).tile_size(16).build()?;

    let mut vmc = ToyVmc::new(n_params, 7);
    println!("VMC + stochastic reconfiguration: {n_params} params, {n_samples} samples/step");

    let mut last_energy = f64::INFINITY;
    for step in 0..steps {
        let (o, e_loc) = vmc.sample(n_samples);
        let e_mean = e_loc.iter().sum::<f64>() / n_samples as f64;

        // Centered log-derivatives and force vector g = ⟨O† ΔE⟩.
        let mut o_mean = vec![0.0; n_params];
        for k in 0..n_params {
            o_mean[k] = (0..n_samples).map(|s| o[(s, k)]).sum::<f64>() / n_samples as f64;
        }
        let mut oc = Matrix::<f64>::zeros(n_samples, n_params);
        for s in 0..n_samples {
            for k in 0..n_params {
                oc[(s, k)] = o[(s, k)] - o_mean[k];
            }
        }
        let mut g = Matrix::<f64>::zeros(n_params, 1);
        for k in 0..n_params {
            g[(k, 0)] = (0..n_samples)
                .map(|s| oc[(s, k)] * (e_loc[s] - e_mean))
                .sum::<f64>()
                / n_samples as f64;
        }

        // S = OᵀO/m + λI — dense Hermitian PSD, the distributed part.
        let mut s_mat = oc.adjoint().matmul(&oc).scale(1.0 / n_samples as f64);
        for k in 0..n_params {
            s_mat[(k, k)] += lambda;
        }

        // Factor once; the same factor could serve multiple solves
        // (e.g. several observables) — the composability the paper sells.
        let factor = ctx.factorize(&s_mat)?;
        let delta = factor.solve(&g)?;

        for k in 0..n_params {
            vmc.theta[k] -= lr * delta[(k, 0)];
        }
        println!("  step {step:2}: ⟨E⟩ = {e_mean:8.4}   ‖δ‖ = {:.3e}", delta.norm_fro());
        last_energy = e_mean;
    }

    println!(
        "\nfinal ⟨E⟩ = {last_energy:.4} — SR loop ran {} distributed solves \
         ({} tile kernels, projected H200 time {:.2} ms)",
        steps,
        ctx.metrics().kernel_launches,
        ctx.projected_time() * 1e3
    );
    Ok(())
}
