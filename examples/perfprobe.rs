//! §Perf micro-probe: native GEMM throughput (the FLOP carrier of the
//! native backend) and the end-to-end potrs wall-clock used as the
//! before/after anchor in EXPERIMENTS.md §Perf.
//!
//! Run: `cargo run --release --example perfprobe`

use jaxmg::coordinator::{ExecMode, JaxMg, Mesh};
use jaxmg::device::SimNode;
use jaxmg::linalg::{dense_gemm_acc, Matrix};
use std::time::Instant;

fn main() {
    // Native GEMM throughput.
    for n in [128usize, 256, 512] {
        let a = Matrix::<f64>::random(n, n, 1);
        let b = Matrix::<f64>::random(n, n, 2);
        let mut c = Matrix::<f64>::zeros(n, n);
        let reps = 3;
        let t0 = Instant::now();
        for _ in 0..reps {
            dense_gemm_acc(&mut c, &a, &b, 1.0);
        }
        let dt = t0.elapsed().as_secs_f64() / reps as f64;
        println!("gemm n={n}: {:.1} ms, {:.2} GFLOP/s", dt * 1e3, 2.0 * (n as f64).powi(3) / dt / 1e9);
    }

    // End-to-end potrs anchor.
    let node = SimNode::new_uniform(8, 1 << 30);
    let ctx = JaxMg::builder()
        .mesh(Mesh::new_1d(node, "x"))
        .tile_size(64)
        .exec_mode(ExecMode::Spmd)
        .build()
        .unwrap();
    let a = Matrix::<f64>::spd_diag(512);
    let b = Matrix::<f64>::ones(512, 1);
    let t0 = Instant::now();
    ctx.potrs(&a, &b).unwrap();
    println!("potrs n=512 T=64 8dev: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
}
