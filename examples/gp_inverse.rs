//! Domain example: Gaussian-process posterior via distributed `potri`.
//!
//! GP regression needs `K⁻¹` (or repeated solves against `K`) for a
//! dense kernel matrix — the classic memory-wall case the paper's
//! `potri` targets (Fig. 3b benchmarks complex128 inversion; GP gives
//! the natural real-valued analogue with a full downstream use of the
//! inverse: posterior mean *and* variance).
//!
//! The serving path shows both halves of the repeat-solve story:
//! `α = K⁻¹y` and `K⁻¹` come out of **one fused solve DAG** (a single
//! factorization feeds the `potrs` and the `potri`), and the online
//! refits that follow — new targets against the same kernel — hit the
//! resident factor cache and skip the `potrf` entirely.
//!
//! Run: `cargo run --release --example gp_inverse`

use jaxmg::coordinator::{DistRoutine, SmallConfig, SolveDag, SolveService};
use jaxmg::linalg::{tol_for, FrobNorm};
use jaxmg::prelude::*;

fn rbf(x: f64, y: f64, ell: f64) -> f64 {
    (-(x - y) * (x - y) / (2.0 * ell * ell)).exp()
}

fn main() -> Result<()> {
    let n_train = 256;
    let n_test = 16;
    let ell = 0.3;
    let noise = 1e-4;

    // Training data: y = sin(4x) + small noise on [0, 1].
    let mut rng = jaxmg::rng::Rng::new(11);
    let xs: Vec<f64> = (0..n_train).map(|i| i as f64 / n_train as f64).collect();
    let ys: Vec<f64> = xs.iter().map(|&x| (4.0 * x).sin() + 0.01 * rng.next_signed()).collect();

    // Dense kernel matrix K + σ²I.
    let mut k = Matrix::<f64>::from_fn(n_train, n_train, |i, j| rbf(xs[i], xs[j], ell));
    for i in 0..n_train {
        k[(i, i)] += noise;
    }

    let node = SimNode::new_uniform(4, 1 << 30);
    let mut cfg = SmallConfig::with_tile(32);
    cfg.factor_cache = true;
    let svc = SolveService::with_small_config(node.clone(), 2, cfg);

    println!("GP posterior: {n_train} training points, RBF ℓ={ell}");

    // α = K⁻¹y and K⁻¹ from one fused chain: the factorization is paid
    // once, the intermediate gather/re-scatter/re-factor of two
    // separate submits vanishes.
    let yv = Matrix::<f64>::from_vec(n_train, 1, ys.clone());
    let t0 = std::time::Instant::now();
    let chain = SolveDag::new(k.clone()).solve(yv.clone()).inverse();
    let mut stages = svc.submit_dag(chain)?.into_iter();
    let (alpha, _) = stages.next().expect("solve stage").wait();
    let (k_inv, s_inv) = stages.next().expect("inverse stage").wait();
    println!(
        "fused potrs+potri chain ({} stages, one factorization): {:.2} s wall (simulator)",
        s_inv.fused_stages,
        t0.elapsed().as_secs_f64()
    );

    // Posterior mean + variance on test points; compare mean to truth.
    println!("\n{:>6} {:>10} {:>10} {:>10}", "x*", "mean", "truth", "std");
    let mut max_err = 0.0f64;
    for t in 0..n_test {
        let xstar = (t as f64 + 0.5) / n_test as f64;
        let kstar = Matrix::<f64>::from_fn(n_train, 1, |i, _| rbf(xs[i], xstar, ell));
        let mean = kstar.adjoint().matmul(&alpha)[(0, 0)];
        let kk = kstar.adjoint().matmul(&k_inv).matmul(&kstar)[(0, 0)];
        let var = (rbf(xstar, xstar, ell) - kk).max(0.0);
        let truth = (4.0 * xstar).sin();
        max_err = max_err.max((mean - truth).abs());
        println!("{xstar:>6.3} {mean:>10.5} {truth:>10.5} {:>10.2e}", var.sqrt());
    }
    assert!(max_err < 0.05, "posterior mean strayed from the truth: {max_err}");
    println!("\nmax |mean − truth| = {max_err:.4}  (interpolation regime)");

    // Online refits: fresh targets against the same kernel. The first
    // solve factors cold and leaves L resident; every later one hits
    // the cache and runs only the triangular stages.
    println!("\nonline refits against the cached kernel factor:");
    for step in 0..5u64 {
        let y2: Vec<f64> =
            xs.iter().map(|&x| (4.0 * x).sin() + 0.05 * ((step as f64 + 1.0) * x).cos()).collect();
        let b = Matrix::<f64>::from_vec(n_train, 1, y2);
        let (x, stats) =
            svc.submit_dist(DistRoutine::Potrs, k.clone(), Some(b.clone()))?.wait();
        let resid = k.matmul(&x).rel_err(&b);
        assert!(resid < tol_for::<f64>(n_train) * 10.0, "refit {step} residual {resid}");
        assert_eq!(
            stats.cache_hit,
            step > 0,
            "refit 0 must factor cold and seed the cache; later refits must hit"
        );
        println!(
            "  step {step}: {:<4} potrf, {:>8.3} ms exec",
            if stats.cache_hit { "skip" } else { "cold" },
            stats.exec_secs() * 1e3
        );
    }

    // Consistency: K · K⁻¹ ≈ I.
    let resid = k.matmul(&k_inv).rel_err(&Matrix::eye(n_train));
    println!("‖K·K⁻¹ − I‖/‖I‖ = {resid:.3e}");

    let m = node.metrics().snapshot();
    println!(
        "cache: {} hits / {} misses (hit rate {:.0}%), {} resident bytes, {} DAG stages fused",
        m.cache_hits,
        m.cache_misses,
        m.cache_hit_rate() * 100.0,
        m.cache_resident_bytes,
        m.dag_fused_stages
    );
    println!("projected H200 time {:.2} ms", node.sim_time() * 1e3);
    svc.drain();
    Ok(())
}
