//! Domain example: Gaussian-process posterior via distributed `potri`.
//!
//! GP regression needs `K⁻¹` (or repeated solves against `K`) for a
//! dense kernel matrix — the classic memory-wall case the paper's
//! `potri` targets (Fig. 3b benchmarks complex128 inversion; GP gives
//! the natural real-valued analogue with a full downstream use of the
//! inverse: posterior mean *and* variance).
//!
//! Run: `cargo run --release --example gp_inverse`

use jaxmg::prelude::*;

fn rbf(x: f64, y: f64, ell: f64) -> f64 {
    (-(x - y) * (x - y) / (2.0 * ell * ell)).exp()
}

fn main() -> Result<()> {
    let n_train = 256;
    let n_test = 16;
    let ell = 0.3;
    let noise = 1e-4;

    // Training data: y = sin(4x) + small noise on [0, 1].
    let mut rng = jaxmg::rng::Rng::new(11);
    let xs: Vec<f64> = (0..n_train).map(|i| i as f64 / n_train as f64).collect();
    let ys: Vec<f64> = xs.iter().map(|&x| (4.0 * x).sin() + 0.01 * rng.next_signed()).collect();

    // Dense kernel matrix K + σ²I.
    let mut k = Matrix::<f64>::from_fn(n_train, n_train, |i, j| rbf(xs[i], xs[j], ell));
    for i in 0..n_train {
        k[(i, i)] += noise;
    }

    let node = SimNode::new_uniform(4, 1 << 30);
    let ctx = JaxMg::builder().mesh(Mesh::new_1d(node, "x")).tile_size(32).build()?;

    println!("GP posterior: {n_train} training points, RBF ℓ={ell}");
    let t0 = std::time::Instant::now();
    let k_inv = ctx.potri(&k)?; // distributed Cholesky inverse
    println!("distributed potri: {:.2} s wall (simulator)", t0.elapsed().as_secs_f64());

    // α = K⁻¹ y.
    let yv = Matrix::<f64>::from_vec(n_train, 1, ys.clone());
    let alpha = k_inv.matmul(&yv);

    // Posterior mean + variance on test points; compare mean to truth.
    println!("\n{:>6} {:>10} {:>10} {:>10}", "x*", "mean", "truth", "std");
    let mut max_err = 0.0f64;
    for t in 0..n_test {
        let xstar = (t as f64 + 0.5) / n_test as f64;
        let kstar = Matrix::<f64>::from_fn(n_train, 1, |i, _| rbf(xs[i], xstar, ell));
        let mean = kstar.adjoint().matmul(&alpha)[(0, 0)];
        let kk = kstar.adjoint().matmul(&k_inv).matmul(&kstar)[(0, 0)];
        let var = (rbf(xstar, xstar, ell) - kk).max(0.0);
        let truth = (4.0 * xstar).sin();
        max_err = max_err.max((mean - truth).abs());
        println!("{xstar:>6.3} {mean:>10.5} {truth:>10.5} {:>10.2e}", var.sqrt());
    }
    assert!(max_err < 0.05, "posterior mean strayed from the truth: {max_err}");
    println!("\nmax |mean − truth| = {max_err:.4}  (interpolation regime)");

    // Consistency: K · K⁻¹ ≈ I.
    use jaxmg::linalg::FrobNorm;
    let resid = k.matmul(&k_inv).rel_err(&Matrix::eye(n_train));
    println!("‖K·K⁻¹ − I‖/‖I‖ = {resid:.3e}");
    println!("projected H200 time {:.2} ms", ctx.projected_time() * 1e3);
    Ok(())
}
