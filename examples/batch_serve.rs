//! Mixed-workload serving demo: one paper-scale solve sharing the
//! `SolveService` with a stream of tiny solves, and the batched
//! small-solve path against the serial one-at-a-time alternative.
//!
//! Run with `cargo run --release --example batch_serve`. The makespan
//! table at the end is recorded in EXPERIMENTS.md.

use jaxmg::batch::SmallRoutine;
use jaxmg::coordinator::{Footprint, SmallConfig};
use jaxmg::costmodel::{GpuCostModel, Predictor};
use jaxmg::layout::BlockCyclic1D;
use jaxmg::linalg::Matrix;
use jaxmg::prelude::*;
use jaxmg::scalar::DType;
use jaxmg::solver::{potrf_dist, potrs_dist, Ctx};
use jaxmg::tile::{DistMatrix, Layout1D};

const NDEV: usize = 4;
const TILE: usize = 64;
const BIG_N: usize = 512;
const SMALL: usize = 128; // tiny solves in the mixed stream

fn small_sizes() -> Vec<usize> {
    // A mix of tiny sizes across two size-classes (16 and 32).
    (0..SMALL).map(|i| 12 + (i % 3) * 9).collect()
}

/// Drive the mixed workload through a service; `small_dim = 0` forces
/// every tiny solve down the distributed path (the serial baseline).
fn run_mixed(small_dim: usize) -> (f64, jaxmg::metrics::MetricsSnapshot) {
    let node = SimNode::new_uniform(NDEV, 1 << 30);
    let mut cfg = SmallConfig::with_tile(TILE);
    cfg.policy.max_batch = 32;
    cfg.policy.small_dim = small_dim;
    let svc = SolveService::with_small_config(node.clone(), 2, cfg);

    // The paper-scale tenant: one big potrs through the ordinary
    // footprint-admitted path, solved with the pipelined schedule.
    let a_big = Matrix::<f64>::spd_diag(BIG_N);
    let b_big = Matrix::<f64>::ones(BIG_N, 1);
    let fp = Footprint::for_routine("potrs", BIG_N, 1, TILE, NDEV, DType::F64).unwrap();
    let node_big = node.clone();
    let big = svc
        .submit(fp, move || {
            let model = GpuCostModel::h200();
            let backend = SolverBackend::<f64>::Native;
            let ctx = Ctx::pipelined(&node_big, &model, &backend);
            let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(BIG_N, TILE, NDEV).unwrap());
            let mut dm = DistMatrix::scatter(&node_big, &a_big, lay).unwrap();
            potrf_dist(&ctx, &mut dm).unwrap();
            potrs_dist(&ctx, &dm, &b_big).unwrap()
        })
        .unwrap();

    // The small-solve traffic, interleaved behind it.
    let smalls: Vec<_> = small_sizes()
        .into_iter()
        .enumerate()
        .map(|(i, n)| {
            let a = Matrix::<f64>::spd_random(n, i as u64);
            let rhs = Matrix::<f64>::random(n, 1, 9000 + i as u64);
            svc.submit_small(SmallRoutine::Potrs, a, Some(rhs)).unwrap()
        })
        .collect();

    svc.flush_small();
    let (x_big, big_stats) = big.wait();
    // diag(1..N)·x = 1 ⇒ x_i = 1/(i+1).
    assert!((x_big[(BIG_N - 1, 0)] - 1.0 / BIG_N as f64).abs() < 1e-10);
    let mut coalesced = 0usize;
    for h in smalls {
        let (x, stats) = h.wait();
        assert!(x.rows() >= 12);
        if stats.batch_size > 1 {
            coalesced += 1;
        }
    }
    svc.drain();
    println!(
        "  small_dim={small_dim:>3}: {coalesced}/{SMALL} tiny solves coalesced, big solve \
         queued {:.1} ms / ran {:.1} ms",
        big_stats.queue_wait_secs() * 1e3,
        big_stats.exec_secs() * 1e3
    );
    (node.sim_time(), node.metrics().snapshot())
}

fn main() {
    println!("== mixed workload: 1 × potrs(n={BIG_N}) + {SMALL} tiny solves (f64, {NDEV} devices) ==\n");

    let (t_batched, m_batched) = run_mixed(4 * TILE);
    let (t_serial, m_serial) = run_mixed(0);

    println!("{:>28} {:>14} {:>14}", "", "coalesced", "serial");
    println!(
        "{:>28} {:>14.3} {:>14.3}",
        "projected makespan [ms]",
        t_batched * 1e3,
        t_serial * 1e3
    );
    println!(
        "{:>28} {:>14} {:>14}",
        "swept buckets",
        m_batched.batch_buckets,
        m_serial.batch_buckets
    );
    println!(
        "{:>28} {:>14.1} {:>14}",
        "mean bucket occupancy",
        m_batched.avg_batch_occupancy(),
        "-"
    );
    println!(
        "{:>28} {:>14.3} {:>14}",
        "mean coalesce wait [µs]",
        m_batched.avg_coalesce_wait() * 1e6,
        "-"
    );
    println!(
        "{:>28} {:>14} {:>14}",
        "peer copies",
        m_batched.peer_copies,
        m_serial.peer_copies
    );
    assert!(
        t_batched < t_serial,
        "coalesced mixed workload {t_batched} !< serial {t_serial}"
    );

    // Where the cost model says to stop batching on this node shape.
    let p = Predictor::h200(NDEV, DType::F64);
    let crossover = p.batched_crossover("potrs", 1, TILE, NDEV, 32);
    if crossover == usize::MAX {
        println!(
            "\ncost-model crossover for potrs on {NDEV} devices (T_A={TILE}, 32-way \
             buckets): batching wins across the whole scanned ladder"
        );
    } else {
        println!(
            "\ncost-model crossover for potrs on {NDEV} devices (T_A={TILE}, 32-way \
             buckets): size-class {crossover}"
        );
    }
    println!("\nbatch_serve OK");
}
