//! End-to-end driver: the full system on a real workload, all layers.
//!
//! Proves the stack composes: Pallas/JAX-authored AOT artifacts loaded
//! through PJRT (L1/L2), executed by the Rust coordinator (L3) over the
//! simulated 8-GPU node — SPMD *and* MPMD pointer reconciliation, §2.1
//! redistribution, all three routines, on the paper's benchmark matrix
//! `A = diag(1..N)`. Reports, per configuration:
//!
//!   * correctness residual (exact solution known),
//!   * measured simulator wall-clock,
//!   * projected H200 wall-clock (the cost model),
//!   * peer-traffic volume,
//!
//! then the headline table: largest solvable N, single GPU vs JAXMg
//! (the paper's §3 claim: N = 524288 potrs float32, >1 TB aggregate).
//!
//! Results are recorded in EXPERIMENTS.md.
//!
//! Run: `make e2e`  (or `cargo run --release --example e2e_driver`)

use jaxmg::coordinator::{BackendKind, ExecMode, JaxMg, Mesh};
use jaxmg::costmodel::Predictor;
use jaxmg::linalg::FrobNorm;
use jaxmg::prelude::*;
use jaxmg::scalar::DType;
use std::time::Instant;

fn ctx(ndev: usize, tile: usize, mode: ExecMode, backend: BackendKind) -> Result<JaxMg> {
    let node = SimNode::new_uniform(ndev, 1 << 30);
    JaxMg::builder()
        .mesh(Mesh::new_1d(node, "x"))
        .tile_size(tile)
        .exec_mode(mode)
        .backend(backend)
        .build()
}

fn main() -> Result<()> {
    let have_artifacts = std::path::Path::new("artifacts/.stamp").exists();
    let backends: &[(BackendKind, &str)] = if have_artifacts {
        &[(BackendKind::Native, "native"), (BackendKind::Xla, "xla-aot")]
    } else {
        eprintln!("note: artifacts/ missing — run `make artifacts` to exercise the AOT path");
        &[(BackendKind::Native, "native")]
    };

    println!("== jaxmg end-to-end driver: 8 simulated GPUs, A = diag(1..N), b = 1 ==\n");

    // ---- potrs over an N sweep, both backends, both exec modes -------
    println!(
        "{:<8} {:<8} {:<6} {:>6} {:>12} {:>14} {:>12} {:>10}",
        "backend", "mode", "T_A", "N", "resid", "wall[s]", "proj[ms]", "peer MiB"
    );
    for &(bk, bk_name) in backends {
        for (mode, mode_name) in [(ExecMode::Spmd, "spmd"), (ExecMode::Mpmd, "mpmd")] {
            // The XLA path stages every tile through PJRT; keep its N
            // bounded so the driver stays snappy.
            let sweep: &[usize] = if bk_name == "xla-aot" { &[64, 128] } else { &[64, 256, 512] };
            for &n in sweep {
                let tile = if bk_name == "xla-aot" { 8 } else { 32 };
                let c = ctx(8, tile, mode, bk)?;
                let a = Matrix::<f32>::spd_diag(n);
                let b = Matrix::<f32>::ones(n, 1);
                c.reset_accounting();
                let t0 = Instant::now();
                let x = c.potrs(&a, &b)?;
                let wall = t0.elapsed().as_secs_f64();
                // Exact solution known: x_i = 1/(i+1).
                let mut err = 0.0f64;
                for i in 0..n {
                    err = err.max((x[(i, 0)] as f64 - 1.0 / (i + 1) as f64).abs());
                }
                let m = c.metrics();
                println!(
                    "{:<8} {:<8} {:<6} {:>6} {:>12.3e} {:>14.3} {:>12.3} {:>10.2}",
                    bk_name,
                    mode_name,
                    tile,
                    n,
                    err,
                    wall,
                    c.projected_time() * 1e3,
                    m.peer_bytes as f64 / (1 << 20) as f64
                );
            }
        }
    }

    // ---- lookahead pipelining: barrier vs 2-step lookahead ------------
    println!("\n== lookahead pipelining: potrf projected makespan (native) ==");
    println!(
        "{:>5} {:>6} {:>6} {:>13} {:>14} {:>7} {:>6}",
        "ndev", "T_A", "N", "barrier[ms]", "lookahead[ms]", "gain", "util"
    );
    for &(ndev, tile, n) in &[(4usize, 16usize, 128usize), (8, 16, 256), (8, 32, 256)] {
        use jaxmg::costmodel::GpuCostModel;
        use jaxmg::solver::{potrf_dist, Ctx, SolverBackend};
        use jaxmg::tile::{DistMatrix, Layout1D};
        let run = |cfg: PipelineConfig| -> (f64, f64) {
            let node = SimNode::new_uniform(ndev, 1 << 28);
            let model = GpuCostModel::h200();
            let backend = SolverBackend::<f32>::Native;
            let a = Matrix::<f32>::spd_diag(n);
            let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
            let mut dm = DistMatrix::scatter(&node, &a, lay).unwrap();
            node.reset_accounting();
            let sctx = Ctx::with_pipeline(&node, &model, &backend, cfg);
            potrf_dist(&sctx, &mut dm).unwrap();
            (node.sim_time(), node.metrics().snapshot().overlap_efficiency())
        };
        let (tb, _) = run(PipelineConfig::barrier());
        let (tl, util) = run(PipelineConfig::lookahead(2));
        println!(
            "{ndev:>5} {tile:>6} {n:>6} {:>13.3} {:>14.3} {:>6.2}x {util:>6.2}",
            tb * 1e3,
            tl * 1e3,
            tb / tl
        );
    }

    // ---- concurrent solve service -------------------------------------
    println!("\n== concurrent solve service: 8 mixed potrs solves, 4 workers ==");
    {
        use jaxmg::costmodel::GpuCostModel;
        use jaxmg::solver::{potrf_dist, potrs_dist, Ctx, SolverBackend};
        use jaxmg::tile::{DistMatrix, Layout1D};
        let ndev = 8;
        let node = SimNode::new_uniform(ndev, 1 << 28);
        let svc = SolveService::new(node.clone(), 4);
        let t0 = Instant::now();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let n = 96 + 32 * (i % 3);
                let tile = 16;
                let fp = Footprint::for_routine("potrs", n, 1, tile, ndev, DType::F64).unwrap();
                let node2 = node.clone();
                svc.submit(fp, move || {
                    let model = GpuCostModel::h200();
                    let backend = SolverBackend::<f64>::Native;
                    let sctx = Ctx::pipelined(&node2, &model, &backend);
                    let a = Matrix::<f64>::spd_diag(n);
                    let b = Matrix::<f64>::ones(n, 1);
                    let lay = Layout1D::BlockCyclic(BlockCyclic1D::new(n, tile, ndev).unwrap());
                    let mut dm = DistMatrix::scatter(&node2, &a, lay).unwrap();
                    potrf_dist(&sctx, &mut dm).unwrap();
                    let x = potrs_dist(&sctx, &dm, &b).unwrap();
                    dm.free().unwrap();
                    let mut err = 0.0f64;
                    for r in 0..n {
                        err = err.max((x[(r, 0)] - 1.0 / (r + 1) as f64).abs());
                    }
                    (n, err)
                })
                .unwrap()
            })
            .collect();
        println!("{:>4} {:>6} {:>12} {:>12} {:>12}", "job", "N", "wait[ms]", "exec[ms]", "resid");
        for (i, h) in handles.into_iter().enumerate() {
            let ((n, err), stats) = h.wait();
            println!(
                "{i:>4} {n:>6} {:>12.2} {:>12.2} {err:>12.3e}",
                stats.queue_wait_secs() * 1e3,
                stats.exec_secs() * 1e3
            );
        }
        let m = node.metrics().snapshot();
        println!(
            "served 8 solves in {:.3} s: avg queue wait {:.2} ms, overlap efficiency {:.2}",
            t0.elapsed().as_secs_f64(),
            m.avg_queue_wait() * 1e3,
            m.overlap_efficiency()
        );
    }

    // ---- factor cache: repeat solves skip the potrf -------------------
    println!("\n== factor cache: 6 repeat potrs against one matrix + a fused DAG ==");
    {
        use jaxmg::coordinator::{DistRoutine, SmallConfig, SolveDag};
        let node = SimNode::new_uniform(8, 1 << 28);
        let mut cfg = SmallConfig::with_tile(16);
        cfg.factor_cache = true;
        let svc = SolveService::with_small_config(node.clone(), 2, cfg);
        let n = 192;
        let a = Matrix::<f64>::spd_diag(n);
        println!("{:>4} {:>6} {:>6} {:>12}", "req", "N", "path", "exec[ms]");
        for i in 0..6u64 {
            let b = Matrix::<f64>::random(n, 1, 77 + i);
            let (_, stats) = svc.submit_dist(DistRoutine::Potrs, a.clone(), Some(b))?.wait();
            assert_eq!(stats.cache_hit, i > 0, "only the first solve may factor cold");
            println!(
                "{i:>4} {n:>6} {:>6} {:>12.3}",
                if stats.cache_hit { "hit" } else { "cold" },
                stats.exec_secs() * 1e3
            );
        }
        // A fused potrf→potrs→potri chain on a second matrix: one
        // admission, one resident layout, three stage results.
        let a2 = Matrix::<f64>::spd_random(n, 31);
        let b2 = Matrix::<f64>::random(n, 1, 32);
        let chain = SolveDag::new(a2).factor().solve(b2).inverse();
        for h in svc.submit_dag(chain)? {
            let (_, stats) = h.wait();
            assert_eq!(stats.fused_stages, 3);
        }
        let m = node.metrics().snapshot();
        println!(
            "cache: {} hits / {} misses (hit rate {:.0}%), {} evictions, \
             {} B resident, {} DAG stages fused",
            m.cache_hits,
            m.cache_misses,
            m.cache_hit_rate() * 100.0,
            m.cache_evictions,
            m.cache_resident_bytes,
            m.dag_fused_stages
        );
        svc.drain();
    }

    // ---- potri + syevd spot checks (paper dtypes) ---------------------
    println!("\n-- potri complex128 / syevd float64 (native backend, spmd) --");
    {
        let c = ctx(8, 16, ExecMode::Spmd, BackendKind::Native)?;
        let n = 192;
        let a = Matrix::<c64>::spd_diag(n);
        c.reset_accounting();
        let t0 = Instant::now();
        let inv = c.potri(&a)?;
        let wall = t0.elapsed().as_secs_f64();
        let resid = a.matmul(&inv).rel_err(&Matrix::eye(n));
        println!(
            "potri  c128 N={n}: resid={resid:.3e} wall={wall:.3}s proj={:.3}ms",
            c.projected_time() * 1e3
        );
    }
    {
        let c = ctx(8, 16, ExecMode::Spmd, BackendKind::Native)?;
        let n = 192;
        let a = Matrix::<f64>::spd_diag(n);
        c.reset_accounting();
        let t0 = Instant::now();
        let (vals, _) = c.syevd(&a)?;
        let wall = t0.elapsed().as_secs_f64();
        let mut err = 0.0f64;
        for i in 0..n {
            err = err.max((vals[i] - (i + 1) as f64).abs());
        }
        println!(
            "syevd  f64  N={n}: max|λᵢ−i|={err:.3e} wall={wall:.3}s proj={:.3}ms",
            c.projected_time() * 1e3
        );
    }

    // ---- headline: capacity table at paper scale ----------------------
    println!("\n== headline: largest solvable N (8 × 143 GB H200, T_A=1024) ==");
    let vram = 143usize * 1000 * 1000 * 1000;
    println!("{:<8} {:>12} {:>12} {:>12} {:>8}", "routine", "dtype", "single-GPU", "jaxmg", "gain");
    for (routine, dt) in [
        ("potrs", DType::F32),
        ("potri", DType::C128),
        ("syevd", DType::F64),
    ] {
        let p = Predictor::h200(8, dt);
        let single = p.single_capacity(routine, vram);
        let dist = p.dist_capacity(routine, vram, 8, 1024);
        println!(
            "{:<8} {:>12} {:>12} {:>12} {:>7.1}x",
            routine,
            dt.name(),
            single,
            dist,
            dist as f64 / single as f64
        );
    }
    println!("\npaper §3: potrs float32 reaches N = 524288 (>1 TB) — see EXPERIMENTS.md");

    // ---- headline: the Fig. 3a crossover at paper scale ---------------
    println!("\n== projected Fig. 3a crossover (potrs f32, T_A=1024) ==");
    let p = Predictor::h200(8, DType::F32);
    println!("{:>9} {:>12} {:>12} {:>9}", "N", "jaxmg[s]", "single[s]", "winner");
    let mut n = 4096usize;
    while n <= 262144 {
        let mg = p.potrs(n, 1024, 8, 1);
        let dn = p.single_potrs(n, 1);
        println!(
            "{n:>9} {mg:>12.4} {dn:>12.4} {:>9}",
            if mg < dn { "jaxmg" } else { "single" }
        );
        n *= 4;
    }
    // ---- optional: end-to-end trace export (JAXMG_TRACE=<dir>) --------
    // `make trace` runs this with tracing on: an open-loop mixed
    // workload on the SPMD front with every span/decision recorded,
    // exported as Chrome-trace JSON (chrome://tracing / Perfetto), a
    // Prometheus text exposition, and the decision log as JSONL — all
    // validated before they land on disk. See OBSERVABILITY.md.
    if let Ok(dir) = std::env::var("JAXMG_TRACE") {
        use jaxmg::coordinator::SloClass;
        use jaxmg::obs::{
            chrome_trace_json, decisions_jsonl, prometheus_text, validate_chrome_json,
        };
        use jaxmg::workload::{ArrivalProcess, OpenLoop, Population};
        println!("\n== trace export: open-loop gp/vmc mix, tracer enabled ==");
        let node = SimNode::new_uniform(4, 1 << 30);
        let svc = SolveService::new(node.clone(), 2);
        node.tracer().enable();
        let gen = OpenLoop::new(
            ArrivalProcess::Poisson { rate_hz: 50_000.0 },
            Population::gp_vmc_mix(),
            31,
        );
        let pending = gen.drive(&node, &svc, 24)?;
        svc.flush_small();
        for p in pending {
            let _ = p.wait();
        }
        svc.drain();
        let tracer = node.tracer();
        let spans = tracer.spans();
        let json = chrome_trace_json(&spans);
        let events = validate_chrome_json(&json).expect("exported chrome trace must validate");
        let hists: Vec<(String, Vec<(u64, u64)>)> =
            [SloClass::Interactive, SloClass::Standard, SloClass::Batch]
                .iter()
                .map(|&c| (c.name().to_string(), node.metrics().class_histogram(c)))
                .collect();
        let prom = prometheus_text(&node.metrics().snapshot(), &hists);
        let decisions = tracer.decisions();
        let jsonl = decisions_jsonl(&decisions);
        std::fs::create_dir_all(&dir).expect("create trace output dir");
        let dir = std::path::Path::new(&dir);
        std::fs::write(dir.join("e2e_trace.json"), &json).expect("write chrome trace");
        std::fs::write(dir.join("e2e_metrics.prom"), &prom).expect("write prometheus text");
        std::fs::write(dir.join("e2e_decisions.jsonl"), &jsonl).expect("write decision log");
        println!(
            "wrote {} span events, {} decisions, drift keys: {} -> {}",
            events,
            decisions.len(),
            tracer.drift().stats().len(),
            dir.display()
        );
        assert!(events > 0, "a traced workload must produce spans");
        assert!(
            decisions.iter().any(|d| d.kind == "arrival"),
            "the open-loop driver must log arrivals"
        );
    }

    println!("\nend-to-end driver complete.");
    Ok(())
}
