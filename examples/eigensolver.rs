//! Domain example: spectrum of a quantum spin chain.
//!
//! The paper comes out of the Center for Computational Quantum Physics
//! and motivates JAXMg with exactly this workload: dense Hermitian
//! eigenproblems that outgrow one GPU. We build the Hamiltonian of a
//! transverse-field Ising chain (small enough to simulate, same code
//! path as the large case) and diagonalize it with the distributed
//! `syevd`, checking the ground-state energy against the exact
//! free-fermion solution.
//!
//! Run: `cargo run --release --example eigensolver`

use jaxmg::prelude::*;

/// Dense H for the open transverse-field Ising chain:
///   H = −J Σ σᶻᵢσᶻᵢ₊₁ − h Σ σˣᵢ  on `l` sites (dimension 2^l).
fn tfim_hamiltonian(l: usize, j: f64, h: f64) -> Matrix<f64> {
    let dim = 1usize << l;
    let mut ham = Matrix::<f64>::zeros(dim, dim);
    for s in 0..dim {
        // σᶻσᶻ bonds: diagonal.
        let mut diag = 0.0;
        for i in 0..l - 1 {
            let zi = if (s >> i) & 1 == 1 { 1.0 } else { -1.0 };
            let zj = if (s >> (i + 1)) & 1 == 1 { 1.0 } else { -1.0 };
            diag -= j * zi * zj;
        }
        ham[(s, s)] = diag;
        // σˣ flips: off-diagonal.
        for i in 0..l {
            let t = s ^ (1 << i);
            ham[(t, s)] -= h;
        }
    }
    ham
}

/// Exact ground-state energy of the open TFIM via free fermions
/// (Jordan–Wigner; single-particle modes of the tridiagonal form).
fn exact_ground_energy(l: usize, j: f64, h: f64) -> f64 {
    // Single-particle Hamiltonian (2l × 2l BdG), solved with our own
    // host eigensolver — the library eats its own dog food.
    let n = 2 * l;
    let mut m = Matrix::<f64>::zeros(n, n);
    // Basis: (c₁..c_l, c†₁..c†_l). A[i][j] = -h δij + J/2 couplings.
    for i in 0..l {
        m[(i, i)] = -h;
        m[(l + i, l + i)] = h;
    }
    for i in 0..l - 1 {
        // hopping + pairing, symmetrized.
        m[(i, i + 1)] -= j / 2.0;
        m[(i + 1, i)] -= j / 2.0;
        m[(l + i, l + i + 1)] += j / 2.0;
        m[(l + i + 1, l + i)] += j / 2.0;
        m[(i, l + i + 1)] += j / 2.0;
        m[(l + i + 1, i)] += j / 2.0;
        m[(i + 1, l + i)] -= j / 2.0;
        m[(l + i, i + 1)] -= j / 2.0;
    }
    let eig = jaxmg::linalg::syevd_host(&m).expect("BdG eigensolve");
    // Ground state fills all negative modes: E0 = Σ_{ε<0} ε / ... each
    // mode appears ±ε; ground energy is sum of the negative ones.
    eig.values.iter().filter(|&&e| e < 0.0).sum::<f64>() / 1.0
}

fn main() -> Result<()> {
    let l = 8; // 8 spins → 256×256 dense Hamiltonian
    let (j, h) = (1.0, 0.75);

    let node = SimNode::new_uniform(4, 1 << 30);
    let ctx = JaxMg::builder().mesh(Mesh::new_1d(node, "x")).tile_size(32).build()?;

    println!("TFIM chain: L={l}, J={j}, h={h}  (dense dim {})", 1 << l);
    let ham = tfim_hamiltonian(l, j, h);

    let t0 = std::time::Instant::now();
    let (vals, vecs) = ctx.syevd(&ham)?;
    println!("distributed syevd: {:.2} s wall (simulator)", t0.elapsed().as_secs_f64());

    let e0 = vals[0];
    let exact = exact_ground_energy(l, j, h);
    println!("ground-state energy: {e0:.8}");
    println!("free-fermion exact : {exact:.8}");
    assert!((e0 - exact).abs() < 1e-6, "ground energy mismatch");

    // Energy gap and eigenvector sanity.
    println!("first excited gap  : {:.8}", vals[1] - vals[0]);
    let dim = 1 << l;
    let gs = vecs.submatrix(0, 0, dim, 1);
    let hgs = ham.matmul(&gs);
    let mut resid = 0.0f64;
    for i in 0..dim {
        resid += (hgs[(i, 0)] - e0 * gs[(i, 0)]).powi(2);
    }
    println!("‖H|0⟩ − E0|0⟩‖     : {:.3e}", resid.sqrt());

    println!(
        "\nprojected H200 time {:.3} ms over {} devices",
        ctx.projected_time() * 1e3,
        ctx.mesh().num_devices()
    );
    Ok(())
}
