# jaxmg build/test harness.
#
#   make build      release build (tier-1, part 1)
#   make test       full test suite (tier-1, part 2)
#   make check      build + tests + clippy -D warnings + fmt --check
#                   + python tests when a toolchain is present
#   make test-xla   the artifact-gated XLA integration suite
#   make artifacts  AOT-lower the Python kernels to HLO artifacts
#   make bench      all benches   |   make e2e  end-to-end driver
#   make bench-redist  redistribution bench in smoke/test mode (small
#                      shapes, same asserted invariants — CI-friendly)
#   make bench-batch   batched small-solve bench in smoke/test mode:
#                      coalesced pod sweeps vs serial distributed path
#                      (asserts the batched makespan win — CI-friendly)
#   make bench-serve   serving-front bench in smoke/test mode: SPMD vs
#                      MPMD parity + worker-kill drill (CI-friendly,
#                      part of `make check`)
#   make bench-grid    grid-stack bench in smoke/test mode: 2D
#                      conversion hops, grid-native potrf (bitwise vs
#                      1D + strict lookahead win), the 1D-vs-2D
#                      analytic ladder, and 2D-aware serving — then
#                      drives examples/syevd_grid (CI-friendly, part
#                      of `make check`)
#   make bench-traffic scheduler bench in smoke/test mode: one bursty
#                      GP/VMC trace under FIFO vs EDF/SJF (asserts the
#                      interactive p99 win, no batch starvation, zero
#                      lost requests — CI-friendly, part of
#                      `make check`)
#   make bench-cache   factor-cache bench in smoke/test mode: repeated
#                      potrs against a resident factor (asserts the
#                      >=10x throughput bar), the fused solve DAG vs
#                      separate submits, and a reuse-correlated fleet
#                      trace (CI-friendly, part of `make check`)
#   make bench-obs     observability bench in smoke/test mode: tracing
#                      on vs off must be bitwise on the sim clock with
#                      bounded host overhead, and drift correction must
#                      tighten the lookahead queue estimates (part of
#                      `make check`)
#   make bench-fabric  multi-node fabric bench in smoke/test mode:
#                      hierarchical ring-of-rings vs flat collectives
#                      (bitwise numerics + the payload-bound win), the
#                      1-node-vs-2-node plan_dist crossover, and
#                      island-confined serving (CI-friendly, part of
#                      `make check`)
#   make bench-mixed   mixed-precision bench in smoke/test mode: the
#                      modeled full-vs-mixed potrs ladder (asserts the
#                      >=25% win at N>=16384 on 8 devices), the
#                      router's (tol, kappa) decision table, and a
#                      simulated end-to-end mixed-vs-full service run
#                      (CI-friendly, part of `make check`)
#   make trace         e2e driver + MPMD kill drill with JAXMG_TRACE
#                      set: exports validated Chrome-trace JSON,
#                      Prometheus text, and JSONL decision logs under
#                      trace_out/

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test check clippy fmt python-tests test-xla bench bench-redist bench-batch bench-serve bench-grid bench-traffic bench-cache bench-obs bench-fabric bench-mixed trace e2e artifacts clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

clippy:
	$(CARGO) clippy --workspace --all-targets -- -D warnings

fmt:
	$(CARGO) fmt --all -- --check

# Run the L1/L2 Python property tests when pytest+jax are importable;
# skip quietly otherwise (the Rust tier-1 does not depend on them).
python-tests:
	@if $(PYTHON) -c "import pytest, jax, hypothesis" 2>/dev/null; then \
		$(PYTHON) -m pytest python/tests -q; \
	else \
		echo "skipping python tests (pytest/jax/hypothesis not importable)"; \
	fi

check: build test clippy fmt python-tests bench-serve bench-grid bench-traffic bench-cache bench-obs bench-fabric bench-mixed

# Artifact-gated XLA integration tests (fail with a pointed message
# when artifacts are absent — that failure mode is itself under test).
test-xla:
	$(CARGO) test --release --test xla_backend -- --ignored

# Artifacts land in rust/artifacts (where the cargo-run tests and
# benches resolve them: test/bench cwd and CARGO_MANIFEST_DIR are the
# package root), with a repo-root symlink for `cargo run` invocations
# whose cwd is the workspace root (examples, CLI).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../rust/artifacts
	touch rust/artifacts/.stamp
	ln -sfn rust/artifacts artifacts

bench:
	$(CARGO) bench

# The redistribution bench doubles as an integration test: smoke mode
# shrinks the shapes but keeps every content/path assertion.
bench-redist:
	REDIST_BENCH_SMOKE=1 $(CARGO) bench --bench redistribution

# The batching bench doubles as an integration test too: smoke mode
# shrinks the workload but keeps the batched-beats-serial assertions.
bench-batch:
	BATCH_BENCH_SMOKE=1 $(CARGO) bench --bench batching

# The serving bench is the MPMD acceptance harness: SPMD-vs-MPMD
# bitwise parity, the exact cudaIpc overhead charge, and the
# worker-kill drill. Smoke mode shrinks shapes, keeps every assertion.
bench-serve:
	SERVE_BENCH_SMOKE=1 $(CARGO) bench --bench serving

# The grid bench is the 2D acceptance harness: grid-native potrf
# bitwise vs 1D, the strict grid lookahead win, the analytic 1D-vs-2D
# ladder, and 2D-aware serving; it then drives the syevd grid example.
bench-grid:
	GRID_BENCH_SMOKE=1 $(CARGO) bench --bench grid
	$(CARGO) run --release --example syevd_grid

# The traffic bench is the scheduler acceptance harness: one bursty
# GP/VMC open-loop trace replayed under FIFO and EDF/SJF; asserts the
# strict interactive-p99 win, batch completion, and zero lost requests.
bench-traffic:
	TRAFFIC_BENCH_SMOKE=1 $(CARGO) bench --bench traffic

# The cache bench is the factor-cache acceptance harness: the repeated
# potrs hit ladder (asserts the >=10x throughput bar), the fused-DAG
# win over three separate submits, and the reuse-correlated fleet
# trace under cache off/on. Smoke mode shrinks rungs, keeps assertions.
bench-cache:
	CACHE_BENCH_SMOKE=1 $(CARGO) bench --bench cache

# The observability bench is the tracing acceptance harness: an
# identical fleet trace with the tracer off and on must land on the
# same simulated nanosecond (tracing is passive), and drift-corrected
# queue estimates must beat the raw Predictor figures on a pipelined
# repeat-solve stream.
bench-obs:
	OBS_BENCH_SMOKE=1 $(CARGO) bench --bench obs

# The fabric bench is the multi-node acceptance harness: hierarchical
# ring-of-rings collectives vs flat dispatch (bitwise factors, strict
# win at the payload-bound rung), the 1-node-vs-2-node routing
# crossover through plan_dist, and island-confined serving.
bench-fabric:
	FABRIC_BENCH_SMOKE=1 $(CARGO) bench --bench fabric

# The mixed bench is the mixed-precision acceptance harness: the
# modeled full-vs-mixed ladder under the real H200 constants (asserts
# the >=25% makespan win at N>=16384), the cost-model router's
# decision table, and a genuinely-refining end-to-end comparison on a
# flop-slowed model. Smoke mode shrinks the ladder, keeps assertions.
bench-mixed:
	MIXED_BENCH_SMOKE=1 $(CARGO) bench --bench mixed

e2e:
	$(CARGO) run --release --example e2e_driver

# Traced runs: the e2e driver and the MPMD kill drill export validated
# Chrome-trace JSON (load in chrome://tracing or ui.perfetto.dev),
# Prometheus-style metrics text, and a JSONL scheduler decision log.
trace:
	JAXMG_TRACE=trace_out $(CARGO) run --release --example e2e_driver
	JAXMG_TRACE=trace_out $(CARGO) run --release --example mpmd_serve
	@ls -l trace_out

clean:
	$(CARGO) clean
