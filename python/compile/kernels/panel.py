"""Layer-2 panel operations: tile Cholesky and triangular solves.

These are O(T³) on a single T×T tile — latency-bound bookkeeping next
to the O(N³) GEMM stream — so they are written as masked `fori_loop`
jnp code (static shapes, no data-dependent control flow) rather than
Pallas kernels, and lowered into the same HLO artifacts.

Complex variants take split re/im planes (the Rust boundary carries no
complex dtypes), recombine internally, and split the result again.
"""

import jax
import jax.numpy as jnp
from jax import lax


def potf2(a):
    """Unblocked lower Cholesky of a T×T tile via a masked fori_loop.

    A non-positive pivot produces NaNs in the affected column (sqrt of
    a negative), which the Rust caller maps to `NotPositiveDefinite`,
    mirroring cuSOLVER's `info > 0`.
    """
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(k, m):
        pivot = jnp.sqrt(m[k, k].real).astype(m.dtype)
        colk = m[:, k]
        lk = jnp.where(idx == k, pivot, jnp.where(idx > k, colk / pivot, jnp.zeros((), m.dtype)))
        # Trailing update on rows/cols > k only.
        mask = (idx[:, None] > k) & (idx[None, :] > k)
        m = m - jnp.where(mask, jnp.outer(lk, lk.conj()), jnp.zeros((), m.dtype))
        return m.at[:, k].set(lk)

    l = lax.fori_loop(0, n, body, a)
    return jnp.tril(l)


def trsm_llnn(l, b):
    """Solve L X = B by masked forward substitution."""
    n = l.shape[0]
    idx = jnp.arange(n)

    def body(i, x):
        li = jnp.where(idx < i, l[i, :], jnp.zeros((), l.dtype))
        xi = (b[i, :] - li @ x) / l[i, i]
        return x.at[i, :].set(xi)

    return lax.fori_loop(0, n, body, jnp.zeros_like(b))


def trsm_llhn(l, b):
    """Solve L^H X = B by masked backward substitution."""
    n = l.shape[0]
    idx = jnp.arange(n)

    def body(t, x):
        i = n - 1 - t
        # (L^H)[i, j] = conj(L[j, i]); only j > i contributes.
        col = jnp.where(idx > i, l[:, i].conj(), jnp.zeros((), l.dtype))
        xi = (b[i, :] - col @ x) / l[i, i].conj()
        return x.at[i, :].set(xi)

    return lax.fori_loop(0, n, body, jnp.zeros_like(b))


def trsm_rlhc(b, l):
    """Solve X L^H = B (right, lower-adjoint) by column substitution."""
    n = l.shape[0]
    idx = jnp.arange(n)

    def body(j, x):
        # X[:, j] = (B[:, j] - X[:, <j] @ conj(L[j, <j])) / conj(L[j, j])
        row = jnp.where(idx < j, l[j, :].conj(), jnp.zeros((), l.dtype))
        xj = (b[:, j] - x @ row) / l[j, j].conj()
        return x.at[:, j].set(xj)

    return lax.fori_loop(0, n, body, jnp.zeros_like(b))


# ---- split-plane complex wrappers ---------------------------------------


def _join(re, im):
    cdtype = jnp.complex64 if re.dtype == jnp.float32 else jnp.complex128
    return re.astype(cdtype) + 1j * im.astype(cdtype)


def _split(z):
    return z.real, z.imag


def cpotf2(a_re, a_im):
    """Split-plane Hermitian tile Cholesky."""
    return _split(potf2(_join(a_re, a_im)))


def ctrsm_llnn(l_re, l_im, b_re, b_im):
    """Split-plane L X = B."""
    return _split(trsm_llnn(_join(l_re, l_im), _join(b_re, b_im)))


def ctrsm_llhn(l_re, l_im, b_re, b_im):
    """Split-plane L^H X = B."""
    return _split(trsm_llhn(_join(l_re, l_im), _join(b_re, b_im)))


def ctrsm_rlhc(b_re, b_im, l_re, l_im):
    """Split-plane X L^H = B."""
    return _split(trsm_rlhc(_join(b_re, b_im), _join(l_re, l_im)))
