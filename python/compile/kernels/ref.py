"""Pure-jnp oracles for every tile kernel.

These are the correctness currency of the Python test suite: the Pallas
kernels (gemm.py) and the panel ops (panel.py) must match these within
dtype tolerance, and the Rust native backend is cross-checked against
the AOT artifacts built from the same functions.

All functions operate on logical (rows, cols) arrays; the Rust side
packs its column-major tiles row-major so indices line up.
"""

import jax.numpy as jnp


def gemm_nn(c, a, b, alpha):
    """C + alpha * A @ B."""
    return c + alpha * (a @ b)


def gemm_nh(c, a, b, alpha):
    """C + alpha * A @ B^H."""
    return c + alpha * (a @ b.conj().T)


def gemm_hn(c, a, b, alpha):
    """C + alpha * A^H @ B."""
    return c + alpha * (a.conj().T @ b)


def potf2(a):
    """Unblocked lower Cholesky of a Hermitian PD tile: A = L L^H.

    jnp.linalg.cholesky is deliberately avoided: the oracle must not
    share code with the implementation under test, so this is the
    textbook column recurrence in numpy-style indexing.
    """
    n = a.shape[0]
    l = jnp.zeros_like(a)
    for j in range(n):
        d = (a[j, j] - (l[j, :j] * l[j, :j].conj()).sum()).real
        ljj = jnp.sqrt(d)
        l = l.at[j, j].set(ljj.astype(a.dtype))
        if j + 1 < n:
            below = a[j + 1 :, j] - l[j + 1 :, :j] @ l[j, :j].conj()
            l = l.at[j + 1 :, j].set(below / ljj.astype(a.dtype))
    return l


def trsm_llnn(l, b):
    """Solve L X = B (left, lower, no transpose)."""
    n = l.shape[0]
    x = jnp.zeros_like(b)
    for i in range(n):
        xi = (b[i, :] - l[i, :i] @ x[:i, :]) / l[i, i]
        x = x.at[i, :].set(xi)
    return x


def trsm_llhn(l, b):
    """Solve L^H X = B (left, lower-adjoint)."""
    n = l.shape[0]
    x = jnp.zeros_like(b)
    for i in reversed(range(n)):
        xi = (b[i, :] - l[i + 1 :, i].conj() @ x[i + 1 :, :]) / l[i, i].conj()
        x = x.at[i, :].set(xi)
    return x


def trsm_rlhc(b, l):
    """Solve X L^H = B (right, lower-adjoint): the potrf panel update."""
    n = l.shape[0]
    x = jnp.zeros_like(b)
    for j in range(n):
        xj = (b[:, j] - x[:, :j] @ l[j, :j].conj()) / l[j, j].conj()
        x = x.at[:, j].set(xj)
    return x
