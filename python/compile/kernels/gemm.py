"""Layer-1 Pallas GEMM tile kernels — the FLOP hot spot.

The paper's compute lives in cuSOLVERMg's CUDA GEMM/SYRK kernels. The
TPU-shaped restatement (DESIGN.md §Hardware-Adaptation): tiles sized for
VMEM, a `BlockSpec` grid expressing the HBM↔VMEM schedule that CUDA
expressed with threadblocks, and `jnp.dot` inner ops that map onto the
MXU systolic array. Three variants cover every contraction the solvers
need (`nn`, `nh`, `hn`), each with a split-plane complex twin (the
Rust↔XLA boundary carries complex data as separate re/im arrays).

Kernels run with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls; real-TPU efficiency is *estimated* from the VMEM
footprint in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned sub-block edge. Tiles of size T are driven by a
# (T/B) × (T/B) grid; T < B degrades to a single block.
BLOCK = 128


def _grid_and_block(t: int):
    b = min(t, BLOCK)
    assert t % b == 0, f"tile size {t} must be a multiple of the block {b}"
    return (t // b, t // b), b


def _gemm_kernel(c_ref, a_ref, b_ref, alpha_ref, o_ref, *, trans):
    """One (bm × bn) output block: o = c + alpha * contract(a, b).

    `trans` selects the contraction: 'nn' a@b, 'nh' a@b^H, 'hn' a^H@b
    (conjugation is a no-op for real planes; complex goes through the
    split-plane kernels below).
    """
    a = a_ref[...]
    b = b_ref[...]
    if trans == "nn":
        prod = jnp.dot(a, b, preferred_element_type=a.dtype)
    elif trans == "nh":
        prod = jnp.dot(a, b.T, preferred_element_type=a.dtype)
    else:  # "hn"
        prod = jnp.dot(a.T, b, preferred_element_type=a.dtype)
    o_ref[...] = c_ref[...] + alpha_ref[0, 0] * prod


def _specs(trans, b, t):
    """BlockSpecs expressing the HBM→VMEM schedule per output block."""
    c_spec = pl.BlockSpec((b, b), lambda i, j: (i, j))
    if trans == "nn":
        a_spec = pl.BlockSpec((b, t), lambda i, j: (i, 0))
        b_spec = pl.BlockSpec((t, b), lambda i, j: (0, j))
    elif trans == "nh":
        a_spec = pl.BlockSpec((b, t), lambda i, j: (i, 0))
        b_spec = pl.BlockSpec((b, t), lambda i, j: (j, 0))
    else:  # "hn"
        a_spec = pl.BlockSpec((t, b), lambda i, j: (0, i))
        b_spec = pl.BlockSpec((t, b), lambda i, j: (0, j))
    alpha_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return [c_spec, a_spec, b_spec, alpha_spec], c_spec


def _pallas_gemm(trans, c, a, b, alpha):
    t = c.shape[0]
    grid, blk = _grid_and_block(t)
    in_specs, out_spec = _specs(trans, blk, t)
    alpha_arr = jnp.asarray(alpha, dtype=c.dtype).reshape(1, 1)
    kern = functools.partial(_gemm_kernel, trans=trans)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((t, t), c.dtype),
        interpret=True,
    )(c, a, b, alpha_arr)


def gemm_nn(c, a, b, alpha):
    """C + alpha * A @ B over T×T real tiles (Pallas)."""
    return _pallas_gemm("nn", c, a, b, alpha)


def gemm_nh(c, a, b, alpha):
    """C + alpha * A @ B^T over T×T real tiles (Pallas)."""
    return _pallas_gemm("nh", c, a, b, alpha)


def gemm_hn(c, a, b, alpha):
    """C + alpha * A^T @ B over T×T real tiles (Pallas)."""
    return _pallas_gemm("hn", c, a, b, alpha)


# ---- split-plane complex variants ---------------------------------------
#
# One complex GEMM = 4 real GEMMs on the planes. Rather than four
# pallas_call round trips we fuse the whole complex block step into one
# kernel: all six planes stream through VMEM once per output block.


def _cgemm_kernel(cr_ref, ci_ref, ar_ref, ai_ref, br_ref, bi_ref, alr_ref, ali_ref,
                  or_ref, oi_ref, *, trans):
    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    dot = lambda x, y: jnp.dot(x, y, preferred_element_type=x.dtype)
    if trans == "nn":
        pr = dot(ar, br) - dot(ai, bi)
        pi = dot(ar, bi) + dot(ai, br)
    elif trans == "nh":  # A @ B^H, B^H = conj(B).T
        pr = dot(ar, br.T) + dot(ai, bi.T)
        pi = dot(ai, br.T) - dot(ar, bi.T)
    else:  # "hn": A^H @ B
        pr = dot(ar.T, br) + dot(ai.T, bi)
        pi = dot(ar.T, bi) - dot(ai.T, br)
    alr = alr_ref[0, 0]
    ali = ali_ref[0, 0]
    or_ref[...] = cr_ref[...] + alr * pr - ali * pi
    oi_ref[...] = ci_ref[...] + alr * pi + ali * pr


def _pallas_cgemm(trans, cr, ci, ar, ai, br, bi, alpha_re, alpha_im):
    t = cr.shape[0]
    grid, blk = _grid_and_block(t)
    [c_spec, a_spec, b_spec, al_spec], out_spec = _specs(trans, blk, t)
    kern = functools.partial(_cgemm_kernel, trans=trans)
    alr = jnp.asarray(alpha_re, dtype=cr.dtype).reshape(1, 1)
    ali = jnp.asarray(alpha_im, dtype=cr.dtype).reshape(1, 1)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[c_spec, c_spec, a_spec, a_spec, b_spec, b_spec, al_spec, al_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((t, t), cr.dtype),
            jax.ShapeDtypeStruct((t, t), cr.dtype),
        ],
        interpret=True,
    )(cr, ci, ar, ai, br, bi, alr, ali)


def cgemm_nn(cr, ci, ar, ai, br, bi, alpha_re, alpha_im):
    """Split-plane complex C + alpha * A @ B."""
    return _pallas_cgemm("nn", cr, ci, ar, ai, br, bi, alpha_re, alpha_im)


def cgemm_nh(cr, ci, ar, ai, br, bi, alpha_re, alpha_im):
    """Split-plane complex C + alpha * A @ B^H."""
    return _pallas_cgemm("nh", cr, ci, ar, ai, br, bi, alpha_re, alpha_im)


def cgemm_hn(cr, ci, ar, ai, br, bi, alpha_re, alpha_im):
    """Split-plane complex C + alpha * A^H @ B."""
    return _pallas_cgemm("hn", cr, ci, ar, ai, br, bi, alpha_re, alpha_im)
