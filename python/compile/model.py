"""Layer-2 model: blocked tile algorithms composed from the L1 kernels.

Two roles:

1. Define the jit-able *tile op* entry points that `aot.py` lowers to
   the per-op HLO artifacts the Rust coordinator executes (the function
   table below).
2. Provide `blocked_potrf` / `blocked_potrs` — whole-matrix blocked
   algorithms composed of the same kernels, demonstrating (and testing)
   that the L1 pieces assemble into the paper's factorizations inside a
   single jitted JAX program. These mirror exactly what the Rust
   coordinator does across devices, but on one array — they are the
   single-device "model" of the distributed computation.
"""

import jax.numpy as jnp

from compile.kernels import gemm, panel


def blocked_potrf(a, t):
    """Blocked right-looking lower Cholesky of a single array, tile size
    `t` (must divide n). Composes potf2 + trsm_rlhc + Pallas gemm_nh —
    the same schedule `solver::potrf_dist` runs across devices.
    """
    n = a.shape[0]
    assert n % t == 0, "blocked_potrf requires t | n"
    l = jnp.zeros_like(a)
    work = a
    for k0 in range(0, n, t):
        k1 = k0 + t
        lkk = panel.potf2(work[k0:k1, k0:k1])
        l = l.at[k0:k1, k0:k1].set(lkk)
        if k1 < n:
            pan = panel.trsm_rlhc(work[k1:, k0:k1], lkk)
            l = l.at[k1:, k0:k1].set(pan)
            # Trailing update tile-by-tile through the Pallas kernel.
            for j0 in range(k1, n, t):
                pj_hat = pan[j0 - k1 : j0 - k1 + t, :]
                for i0 in range(j0, n, t):
                    pi = pan[i0 - k1 : i0 - k1 + t, :]
                    blk = gemm.gemm_nh(
                        work[i0 : i0 + t, j0 : j0 + t], pi, pj_hat,
                        jnp.asarray(-1.0, a.dtype),
                    )
                    work = work.at[i0 : i0 + t, j0 : j0 + t].set(blk)
    return l


def blocked_potrs(l, b, t):
    """Blocked forward+backward substitution against the blocked factor."""
    n = l.shape[0]
    assert n % t == 0
    y = b
    for k0 in range(0, n, t):
        k1 = k0 + t
        yk = panel.trsm_llnn(l[k0:k1, k0:k1], y[k0:k1, :])
        y = y.at[k0:k1, :].set(yk)
        if k1 < n:
            upd = l[k1:, k0:k1] @ yk
            y = y.at[k1:, :].add(-upd)
    x = y
    for k0 in reversed(range(0, n, t)):
        k1 = k0 + t
        xk = x[k0:k1, :]
        if k1 < n:
            xk = xk - l[k1:, k0:k1].conj().T @ x[k1:, :]
        xk = panel.trsm_llhn(l[k0:k1, k0:k1], xk)
        x = x.at[k0:k1, :].set(xk)
    return x


def blocked_trtri(l, t):
    """Blocked lower-triangular inverse X = L^-1, tile size `t` | n.

    Column-block forward substitution against identity blocks — the
    single-array model of `solver::potri_dist` phase 1.
    """
    n = l.shape[0]
    assert n % t == 0
    x = jnp.zeros_like(l)
    for k0 in range(0, n, t):
        k1 = k0 + t
        # Running RHS tail: rows k0.., identity block on top.
        tail = jnp.zeros((n - k0, t), l.dtype).at[:t, :].set(jnp.eye(t, dtype=l.dtype))
        for j0 in range(k0, n, t):
            j1 = j0 + t
            z = panel.trsm_llnn(l[j0:j1, j0:j1], tail[j0 - k0 : j1 - k0, :])
            x = x.at[j0:j1, k0:k1].set(z)
            if j1 < n:
                tail = tail.at[j1 - k0 :, :].add(-(l[j1:, j0:j1] @ z))
    return x


def blocked_potri(l, t):
    """A^-1 = X^H X from the blocked factor (phase 2 of potri)."""
    x = blocked_trtri(l, t)
    return x.conj().T @ x


# ---- the artifact table ---------------------------------------------------
#
# op name -> (callable, input signature builder). Signatures are built
# by aot.py from (dtype, T). Real ops take real tiles; complex ops take
# split planes. GEMM ops additionally take scalar alpha plane(s).

REAL_OPS = {
    "potf2": (panel.potf2, "A"),
    "trsm_rlhc": (panel.trsm_rlhc, "AB"),
    "trsm_llnn": (panel.trsm_llnn, "AB"),
    "trsm_llhn": (panel.trsm_llhn, "AB"),
    "gemm_nn": (gemm.gemm_nn, "CABa"),
    "gemm_nh": (gemm.gemm_nh, "CABa"),
    "gemm_hn": (gemm.gemm_hn, "CABa"),
}

COMPLEX_OPS = {
    "cpotf2": (panel.cpotf2, "A"),
    "ctrsm_rlhc": (panel.ctrsm_rlhc, "AB"),
    "ctrsm_llnn": (panel.ctrsm_llnn, "AB"),
    "ctrsm_llhn": (panel.ctrsm_llhn, "AB"),
    "cgemm_nn": (gemm.cgemm_nn, "CABa"),
    "cgemm_nh": (gemm.cgemm_nh, "CABa"),
    "cgemm_hn": (gemm.cgemm_hn, "CABa"),
}
