"""AOT compile path: lower every tile op × dtype × tile size to HLO text.

Run once by `make artifacts`; the Rust coordinator loads the emitted
`artifacts/<op>_<dtype>_<T>.hlo.txt` files through the PJRT C API and
Python never appears on the solve path again.

HLO *text* (not `.serialize()`) is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out ../artifacts [--tiles 8,32,64]
"""

import argparse
import pathlib
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def specs_for(sig: str, dtype, t: int, complex_planes: bool):
    """Build the ShapeDtypeStruct argument list for an op signature."""
    tile = jax.ShapeDtypeStruct((t, t), dtype)
    scalar = jax.ShapeDtypeStruct((), dtype)
    n_tiles = {"A": 1, "AB": 2, "CABa": 3}[sig]
    per_tile = 2 if complex_planes else 1
    args = [tile] * (n_tiles * per_tile)
    if sig == "CABa":
        args += [scalar] * per_tile
    return args


def lower_op(name: str, fn, sig: str, dtype, t: int, complex_planes: bool) -> str:
    args = specs_for(sig, dtype, t, complex_planes)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--tiles", default="8,32,64", help="comma-separated tile sizes T_A")
    ap.add_argument("--only", default=None, help="lower only ops containing this substring")
    args = ap.parse_args()

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    tiles = [int(x) for x in args.tiles.split(",") if x]

    jobs = []
    for t in tiles:
        for tok, dtype in (("f32", jnp.float32), ("f64", jnp.float64)):
            for name, (fn, sig) in model.REAL_OPS.items():
                jobs.append((f"{name}_{tok}_{t}", fn, sig, dtype, t, False))
            for name, (fn, sig) in model.COMPLEX_OPS.items():
                jobs.append((f"{name}_{tok}_{t}", fn, sig, dtype, t, True))

    written = skipped = 0
    for basename, fn, sig, dtype, t, cplx in jobs:
        if args.only and args.only not in basename:
            continue
        path = out / f"{basename}.hlo.txt"
        if path.exists():
            skipped += 1
            continue
        text = lower_op(basename, fn, sig, dtype, t, cplx)
        path.write_text(text)
        written += 1
        print(f"  lowered {basename}.hlo.txt ({len(text)} chars)")

    # Stamp file lets make skip the whole step when inputs are unchanged.
    (out / ".stamp").write_text(f"ops={written + skipped}\n")
    print(f"AOT artifacts: {written} written, {skipped} up to date -> {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
