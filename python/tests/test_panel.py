"""L2 panel ops (potf2 / trsm family) vs the oracle, plus the blocked
whole-matrix compositions in model.py.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import panel, ref


def spd(seed, n, dtype):
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((n, n))
    if np.issubdtype(dtype, np.complexfloating):
        b = b + 1j * rng.standard_normal((n, n))
    a = b.conj().T @ b + n * np.eye(n)
    return a.astype(dtype)


def lower_factor(seed, n, dtype):
    return np.asarray(ref.potf2(jnp.asarray(spd(seed, n, dtype))))


@pytest.mark.parametrize("n", [1, 2, 5, 8, 16])
@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.complex128])
def test_potf2_matches_ref(n, dtype):
    a = spd(1, n, dtype)
    got = np.asarray(panel.potf2(jnp.asarray(a)))
    exp = np.asarray(ref.potf2(jnp.asarray(a)))
    tol = 1e-4 if dtype == np.float32 else 1e-11
    np.testing.assert_allclose(got, exp, rtol=tol, atol=tol)
    # Reconstruction.
    np.testing.assert_allclose(got @ got.conj().T, a, rtol=tol * 10, atol=tol * 10)


@pytest.mark.parametrize("op", ["trsm_llnn", "trsm_llhn"])
@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
def test_left_trsm_matches_ref(op, dtype):
    l = lower_factor(2, 8, dtype)
    b = spd(3, 8, dtype)
    got = np.asarray(getattr(panel, op)(jnp.asarray(l), jnp.asarray(b)))
    exp = np.asarray(getattr(ref, op)(jnp.asarray(l), jnp.asarray(b)))
    np.testing.assert_allclose(got, exp, rtol=1e-11, atol=1e-11)


@pytest.mark.parametrize("dtype", [np.float64, np.complex128])
def test_right_trsm_matches_ref(dtype):
    l = lower_factor(4, 6, dtype)
    b = spd(5, 6, dtype)
    got = np.asarray(panel.trsm_rlhc(jnp.asarray(b), jnp.asarray(l)))
    exp = np.asarray(ref.trsm_rlhc(jnp.asarray(b), jnp.asarray(l)))
    np.testing.assert_allclose(got, exp, rtol=1e-11, atol=1e-11)


def test_cpotf2_split_planes():
    a = spd(6, 8, np.complex128)
    lr, li = panel.cpotf2(jnp.asarray(a.real), jnp.asarray(a.imag))
    l = np.asarray(lr) + 1j * np.asarray(li)
    np.testing.assert_allclose(l @ l.conj().T, a, rtol=1e-10, atol=1e-10)


def test_potf2_nonpd_gives_nan():
    """Non-PD pivot must surface as NaN (the Rust side's info>0 signal)."""
    a = np.eye(4)
    a[2, 2] = -1.0
    l = np.asarray(panel.potf2(jnp.asarray(a)))
    assert np.isnan(l[2:, 2:]).any()


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([2, 4, 8, 12]), seed=st.integers(0, 2**31 - 1))
def test_potf2_property(n, seed):
    a = spd(seed, n, np.float64)
    l = np.asarray(panel.potf2(jnp.asarray(a)))
    assert np.allclose(np.triu(l, 1), 0.0)
    np.testing.assert_allclose(l @ l.T, a, rtol=1e-10, atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_trsm_solves_property(seed):
    l = lower_factor(seed, 8, np.float64)
    x = np.random.default_rng(seed).standard_normal((8, 3))
    b = l @ x
    got = np.asarray(panel.trsm_llnn(jnp.asarray(l), jnp.asarray(b)))
    np.testing.assert_allclose(got, x, rtol=1e-9, atol=1e-9)


# ---- blocked model compositions -------------------------------------------


@pytest.mark.parametrize("n,t", [(16, 4), (32, 8), (24, 8)])
def test_blocked_potrf_matches_unblocked(n, t):
    if n % t:
        pytest.skip("t must divide n")
    a = spd(7, n, np.float64)
    l = np.asarray(model.blocked_potrf(jnp.asarray(a), t))
    exp = np.asarray(ref.potf2(jnp.asarray(a)))
    np.testing.assert_allclose(l, exp, rtol=1e-10, atol=1e-10)


def test_blocked_potrs_solves():
    n, t = 24, 8
    a = spd(8, n, np.float64)
    x_true = np.random.default_rng(9).standard_normal((n, 2))
    b = a @ x_true
    l = model.blocked_potrf(jnp.asarray(a), t)
    x = np.asarray(model.blocked_potrs(l, jnp.asarray(b), t))
    np.testing.assert_allclose(x, x_true, rtol=1e-9, atol=1e-9)


def test_blocked_potrf_jits():
    """The whole blocked factorization must stay inside one jit."""
    n, t = 16, 8
    a = spd(10, n, np.float64)
    f = jax.jit(lambda m: model.blocked_potrf(m, t))
    l = np.asarray(f(jnp.asarray(a)))
    np.testing.assert_allclose(l @ l.T, a, rtol=1e-10, atol=1e-10)


def test_blocked_trtri_inverts():
    n, t = 24, 8
    a = spd(11, n, np.float64)
    l = np.asarray(ref.potf2(jnp.asarray(a)))
    x = np.asarray(model.blocked_trtri(jnp.asarray(l), t))
    np.testing.assert_allclose(x @ l, np.eye(n), rtol=1e-9, atol=1e-9)
    # Stays lower triangular.
    assert np.allclose(np.triu(x, 1), 0.0)


def test_blocked_potri_matches_inverse():
    n, t = 16, 4
    a = spd(12, n, np.complex128)
    l = ref.potf2(jnp.asarray(a))
    inv = np.asarray(model.blocked_potri(l, t))
    np.testing.assert_allclose(a @ inv, np.eye(n), rtol=1e-9, atol=1e-9)
