"""L1 correctness: Pallas GEMM kernels vs the pure-jnp oracle.

Hypothesis sweeps tile sizes, dtypes and alpha values; every property
asserts allclose against ref.py at dtype-appropriate tolerance. This is
the core correctness signal for the AOT artifacts (aot.py lowers the
same functions these tests exercise).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm, ref

TILES = [4, 8, 16]
REAL_DTYPES = [np.float32, np.float64]


def rng_tile(seed, t, dtype):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((t, t))
    if np.issubdtype(dtype, np.complexfloating):
        a = a + 1j * rng.standard_normal((t, t))
    return a.astype(dtype)


def tol(dtype):
    return 5e-5 if np.dtype(dtype).itemsize <= 8 and np.dtype(dtype).kind == "f" and np.dtype(dtype).itemsize == 4 or dtype == np.complex64 else 1e-12


@pytest.mark.parametrize("t", TILES)
@pytest.mark.parametrize("dtype", REAL_DTYPES)
@pytest.mark.parametrize("trans", ["nn", "nh", "hn"])
def test_real_gemm_matches_ref(t, dtype, trans):
    c = rng_tile(1, t, dtype)
    a = rng_tile(2, t, dtype)
    b = rng_tile(3, t, dtype)
    alpha = dtype(-1.0)
    pal = getattr(gemm, f"gemm_{trans}")(c, a, b, alpha)
    exp = getattr(ref, f"gemm_{trans}")(c, a, b, alpha)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(exp), rtol=tol(dtype), atol=tol(dtype))


@pytest.mark.parametrize("t", TILES)
@pytest.mark.parametrize("planes,cdtype", [(np.float32, np.complex64), (np.float64, np.complex128)])
@pytest.mark.parametrize("trans", ["nn", "nh", "hn"])
def test_complex_gemm_matches_ref(t, planes, cdtype, trans):
    c = rng_tile(4, t, cdtype)
    a = rng_tile(5, t, cdtype)
    b = rng_tile(6, t, cdtype)
    alpha = cdtype(0.5 - 2.0j)
    out_re, out_im = getattr(gemm, f"cgemm_{trans}")(
        c.real.astype(planes), c.imag.astype(planes),
        a.real.astype(planes), a.imag.astype(planes),
        b.real.astype(planes), b.imag.astype(planes),
        planes(alpha.real), planes(alpha.imag),
    )
    exp = getattr(ref, f"gemm_{trans}")(c, a, b, alpha)
    got = np.asarray(out_re) + 1j * np.asarray(out_im)
    np.testing.assert_allclose(got, np.asarray(exp), rtol=tol(cdtype), atol=tol(cdtype))


@settings(max_examples=25, deadline=None)
@given(
    t=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
    alpha=st.floats(-3, 3, allow_nan=False),
    trans=st.sampled_from(["nn", "nh", "hn"]),
)
def test_gemm_property_f64(t, seed, alpha, trans):
    """Property: Pallas == oracle for arbitrary seeds/shapes/alphas."""
    c = rng_tile(seed, t, np.float64)
    a = rng_tile(seed + 1, t, np.float64)
    b = rng_tile(seed + 2, t, np.float64)
    pal = getattr(gemm, f"gemm_{trans}")(c, a, b, np.float64(alpha))
    exp = getattr(ref, f"gemm_{trans}")(c, a, b, np.float64(alpha))
    np.testing.assert_allclose(np.asarray(pal), np.asarray(exp), rtol=1e-12, atol=1e-12)


@settings(max_examples=15, deadline=None)
@given(t=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_cgemm_property_c128(t, seed):
    c = rng_tile(seed, t, np.complex128)
    a = rng_tile(seed + 1, t, np.complex128)
    b = rng_tile(seed + 2, t, np.complex128)
    out_re, out_im = gemm.cgemm_nn(
        c.real, c.imag, a.real, a.imag, b.real, b.imag, np.float64(1.0), np.float64(0.0)
    )
    exp = ref.gemm_nn(c, a, b, 1.0)
    got = np.asarray(out_re) + 1j * np.asarray(out_im)
    np.testing.assert_allclose(got, np.asarray(exp), rtol=1e-12, atol=1e-12)


def test_gemm_zero_alpha_is_identity():
    c = rng_tile(7, 8, np.float64)
    a = rng_tile(8, 8, np.float64)
    b = rng_tile(9, 8, np.float64)
    out = gemm.gemm_nn(c, a, b, np.float64(0.0))
    np.testing.assert_allclose(np.asarray(out), c, rtol=0, atol=0)


def test_gemm_block_grid_larger_tile():
    """T > BLOCK exercises the multi-block VMEM grid path."""
    t = 256
    c = rng_tile(10, t, np.float32)
    a = rng_tile(11, t, np.float32)
    b = rng_tile(12, t, np.float32)
    out = gemm.gemm_nn(c, a, b, np.float32(1.0))
    exp = ref.gemm_nn(c, a, b, np.float32(1.0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=2e-3, atol=2e-3)
